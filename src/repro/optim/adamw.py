"""AdamW in pure JAX (no optax offline) with global-norm clipping.

Moments are f32 regardless of parameter dtype and inherit the parameter
sharding (ZeRO-style: 2-D sharded parameters ⇒ 2-D sharded optimizer state
for free under GSPMD).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(grads, state: AdamWState, params, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: float = 1.0) -> Tuple[Any, AdamWState, jnp.ndarray]:
    if grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    t = step.astype(jnp.float32)
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g.astype(jnp.float32),
                     state.m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2)
                     * jnp.square(g.astype(jnp.float32)), state.v, grads)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, mm, vv):
        u = (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
        # decoupled weight decay on matrices only (norms/bias excluded by ndim)
        wd = weight_decay if p.ndim >= 2 else 0.0
        return (p.astype(jnp.float32)
                - lr * (u + wd * p.astype(jnp.float32))).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step, m, v), gnorm
