"""Error-feedback top-k gradient compression (Stich et al. / DGC-style).

Distributed-optimization trick for the 1000+-node posture: before the
cross-pod gradient all-reduce, each leaf keeps only its top ``ratio``
fraction of entries by magnitude; the residual is carried into the next
step's gradient (error feedback), which preserves convergence. Sparsifying
before the 'pod'-axis reduction cuts the slowest-link collective bytes by
~1/ratio. Applied leaf-wise with static k (= ratio·size) so shapes stay
fixed under jit; the compressed tensor is re-densified (scatter) because
GSPMD collectives are dense — the win on real hardware comes from chunked
allreduce of the (values, indices) pairs, which ships in
``distrib.collectives.sparse_allreduce``.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    residual: Any  # same structure/shapes as grads


def compression_init(params) -> CompressionState:
    return CompressionState(
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _topk_mask(x: jnp.ndarray, ratio: float) -> jnp.ndarray:
    flat = jnp.abs(x).reshape(-1)
    k = max(1, int(flat.shape[0] * ratio))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.abs(x) >= thresh


def compress_grads(grads, state: CompressionState,
                   ratio: float) -> Tuple[Any, CompressionState]:
    """Returns (sparsified grads, new residual state)."""
    if ratio >= 1.0:
        return grads, state

    def one(g, r):
        acc = g.astype(jnp.float32) + r
        mask = _topk_mask(acc, ratio)
        sent = jnp.where(mask, acc, 0.0)
        return sent.astype(g.dtype), acc - sent

    pairs = jax.tree.map(one, grads, state.residual)
    sent = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda p: p[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return sent, CompressionState(resid)
