"""Closed-loop RL serving controller (DESIGN.md §9).

The paper's thesis is RL-adaptive recomputation; this package extends it
from the PEM vertex mask to the serving runtime itself. A
:class:`ControllerEnv` turns the runtime's existing telemetry (queue
occupancy, back-pressure counters, per-stage percentiles, RWR sweep
counts, delivered lag) into a bounded observation vector and exposes a
discrete knob-ladder action space over the live ``RuntimeKnobs``
(micro-batch window, shed threshold, ``rwr_tol``); a
:class:`ServingController` wraps the upgraded ``core.dqn`` learner
(double-DQN + n-step returns) around it, trained against a
goodput/SLO-violation reward from the ``AckLedger``, deciding at
micro-batch boundaries on the ingress side. ``mode='frozen'`` is pure
greedy inference (replayable); ``mode='off'`` builds nothing at all.
"""

from repro.control.agent import ServingController
from repro.control.env import (ACTION_NAMES, FRESHNESS_OBS_DIM, N_ACTIONS,
                               OBS_DIM, ControllerEnv, obs_dim)

__all__ = [
    "ACTION_NAMES", "N_ACTIONS", "OBS_DIM", "FRESHNESS_OBS_DIM", "obs_dim",
    "ControllerEnv", "ServingController",
]
