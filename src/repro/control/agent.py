"""The serving controller — upgraded DQN over the knob-ladder env.

``ServingController`` composes a :class:`~repro.control.env.ControllerEnv`
with the upgraded ``core.dqn`` learner (double-DQN + n-step returns, see
``DQNSpec``) and runs the decision loop the runtime hooks call at
micro-batch boundaries on the ingress side:

* every ``ControlConfig.decide_every`` batches: observe → credit the
  reward for the *previous* action (train mode) → pick the next action
  (ε-greedy in ``train``, pure greedy in ``frozen``) → move the knobs.
* ``end_episode`` closes the MDP episode (final ``done`` transition,
  flushing the learner's n-step window) so multi-episode training over
  workload replays is well-formed.

Frozen mode consumes no exploration RNG and never learns — given the
same observations it replays the same decisions, which is what the
replay-repeatability tests pin. The controller checkpoints through
``Engine.save/load`` alongside the PEM agent (the engine carries an
optional ``control`` attachment whose ``state_dict`` lands in the same
checkpoint tree).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.config.base import ControlConfig
from repro.control.env import N_ACTIONS, ControllerEnv, obs_dim
from repro.core.dqn import DQNAgent, Transition
from repro.runtime.runtime import AckLedger, RuntimeKnobs
from repro.serving.server import MatchServer


class ServingController:
    """Decision loop + learner; see module docstring."""

    def __init__(self, server: MatchServer, knobs: RuntimeKnobs,
                 ledger: AckLedger, ccfg: ControlConfig,
                 freshness=None):
        if ccfg.mode not in ("train", "frozen"):
            raise ValueError(f"unknown control mode {ccfg.mode!r} "
                             "(off-mode builds no controller)")
        self.ccfg = ccfg
        self.mode = ccfg.mode
        self.env = ControllerEnv(server, knobs, ledger, ccfg,
                                 freshness=freshness)
        # the env fixes the interface shape (12 dims, +2 when the
        # freshness flag is on); the spec's other fields
        # (double/n_step/lr/...) stay caller-configurable
        spec = dataclasses.replace(ccfg.dqn, obs_dim=obs_dim(ccfg),
                                   n_actions=N_ACTIONS)
        self.agent = DQNAgent(spec, seed=ccfg.seed)
        self._batches = 0
        self._prev: Optional[Tuple[np.ndarray, int]] = None
        self.n_decisions = 0
        self.n_episodes = 0
        self.losses: List[float] = []
        # (obs, action, reward-credited-this-decision) — the replayable
        # decision log the determinism tests compare
        self.history: List[Tuple[Tuple[float, ...], int, float]] = []

    def freeze(self) -> None:
        """Switch to pure greedy inference (train-then-freeze runs)."""
        self.mode = "frozen"
        self._prev = None

    # -- runtime hooks --------------------------------------------------------

    def begin_episode(self) -> None:
        """Episode start: knobs return to the configured baseline (every
        episode — training or frozen evaluation — starts from the same
        operating point the static config would) and the env's interval
        baseline re-anchors (the caller may have reset the server or
        ledger since the last episode)."""
        self.env.reset_knobs()
        self.env.rebaseline()
        self._prev = None
        self._batches = 0

    def on_batch(self, n_events: int, service_clock_s: float,
                 now: float) -> None:
        """Micro-batch boundary hook (ingress thread / sync driver)."""
        self.env.note_batch(n_events, service_clock_s)
        self._batches += 1
        if self._batches % self.ccfg.decide_every:
            return
        obs = self.env.observation(now)
        reward = self.env.reward(mark=True)
        if self.mode == "train" and self._prev is not None:
            p_obs, p_act = self._prev
            self.losses.append(self.agent.observe(
                Transition(p_obs, p_act, reward, obs, False)))
        action = self.agent.act(obs, greedy=self.mode == "frozen")
        self.env.apply(action)
        self._prev = (obs, action)
        self.n_decisions += 1
        self.history.append((tuple(float(x) for x in obs), action, reward))

    def end_episode(self, now: float) -> None:
        """Close the episode: final ``done`` transition (train mode) and
        interval reset, so back-to-back workload replays are separate
        MDP episodes."""
        if self.mode == "train" and self._prev is not None:
            obs = self.env.observation(now)
            reward = self.env.reward(mark=True)
            p_obs, p_act = self._prev
            self.losses.append(self.agent.observe(
                Transition(p_obs, p_act, reward, obs, True)))
        else:
            self.env.reward(mark=True)  # reset the interval baseline
        self._prev = None
        self._batches = 0
        self.n_episodes += 1

    # -- persistence (Engine.save/load rides this) ----------------------------

    def state_dict(self) -> Dict:
        ks = self.env.knob_state()
        return {
            "agent": self.agent.state_dict(),
            "knobs": {k: np.asarray(v, np.int64) for k, v in ks.items()},
            "n_decisions": np.asarray(self.n_decisions, np.int64),
            "n_episodes": np.asarray(self.n_episodes, np.int64),
        }

    def load_state_dict(self, sd: Dict) -> None:
        self.agent.load_state_dict(sd["agent"])
        self.env.load_knob_state({k: int(v)
                                  for k, v in sd["knobs"].items()})
        self.n_decisions = int(sd["n_decisions"])
        self.n_episodes = int(sd["n_episodes"])
        self._prev = None
