"""Controller environment — observations, knob-ladder actions, reward.

The environment is deliberately *assembled from what the runtime already
measures* (DESIGN.md §9): queue occupancy and back-pressure counters,
the ``stage_*`` per-stage latency percentiles PR 7 added (0.0 when
tracing is off — ``Telemetry.latency_percentile`` returns 0 for absent
channels), the engine's adaptive-RWR sweep counter, and the
``AckLedger``'s delivered-lag frontier. Two normalization rules keep the
vector well-behaved AND deterministic under a ``VirtualClock``:

* every time-valued component is measured through the injected clock
  (delivered lag, clock-timed device service) or a latency channel that
  is absent in deterministic tests — never ``time.*`` directly;
* every component is a bounded ratio (occupancy fractions, per-event
  fractions, ladder positions), clipped where the underlying quantity is
  unbounded (lag).

Actions move one knob one rung along a bounded ladder per decision:
window ×2/÷2, shed threshold (queue depth) ×2/÷2, ``rwr_tol`` one rung
up/down its discrete ladder (a *bounded* set — ``rwr_tol`` is a static
jit argument, so the ladder bounds recompilation), plus no-op. Ladder
bounds make every reachable configuration a valid static config, so the
learned policy's advantage over static baselines is pure adaptivity.

The reward is the ledger's goodput curve, per event of *demand*
accounted in the decision interval::

    r = (Δgood − w·Δviol − Δdropped − Δthrottled)
        / max(Δgood + Δviol + Δdropped + Δthrottled, 1)

Good events (acked within the SLO) pay +1, SLO violations −w
(``ControlConfig.viol_weight``), and shed events −1 — so the controller
cannot game the SLO by shedding everything. Throttled demand — arrivals
clients held back because delivered lag was high (the closed-loop
source's modulation accounting) — also pays −1: without it the
controller would not feel the demand a laggy configuration silently
loses, and "lag so hard clients stop sending" would look reward-neutral
while the serving bench scores it as lost goodput. Open-loop runs have
no closed-loop source on the ledger and the term is 0; under a
``VirtualClock`` lag is always 0 so the term is 0 there too
(determinism tests unchanged). r is bounded in [−max(w, 1), 1].
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.config.base import ControlConfig
from repro.runtime.runtime import AckLedger, RuntimeKnobs
from repro.serving.server import MatchServer

OBS_DIM = 12
# extra dims appended when ControlConfig.freshness_obs is on: worst
# per-query staleness (in SLOs, clipped like lag) + worst fast-window
# burn rate — the FreshnessLedger's pair (DESIGN.md §11)
FRESHNESS_OBS_DIM = 2
ACTION_NAMES: Tuple[str, ...] = (
    "noop", "window_up", "window_down", "depth_up", "depth_down",
    "tol_up", "tol_down")
N_ACTIONS = len(ACTION_NAMES)

_LAG_CLIP = 8.0  # lag is unbounded; clip at 8 SLOs


def obs_dim(ccfg: ControlConfig) -> int:
    """The observation width this config produces — 12 pinned dims, plus
    the freshness pair behind the flag (tests pin 12 with it off)."""
    return OBS_DIM + (FRESHNESS_OBS_DIM if ccfg.freshness_obs else 0)


def _ladder_from(value: int, floor: int = 8) -> Tuple[int, ...]:
    """Derive a ×2 ladder ending at the configured value (the static
    config is the ladder's top rung; the controller can only tighten)."""
    rungs: List[int] = []
    v = int(value)
    while v >= floor and len(rungs) < 4:
        rungs.append(v)
        v //= 2
    if not rungs:
        rungs = [int(value)]
    return tuple(sorted(rungs))


class ControllerEnv:
    """Observation/action surface between one server and its controller."""

    def __init__(self, server: MatchServer, knobs: RuntimeKnobs,
                 ledger: AckLedger, ccfg: ControlConfig,
                 freshness=None):
        self.server = server
        self.knobs = knobs
        self.ledger = ledger
        self.ccfg = ccfg
        # per-query FreshnessLedger (None = feature off or no ledger in
        # this runtime: the appended dims read as zeros, so the flagged
        # layout is still well-defined without one)
        self.freshness = freshness
        serving = server.serving
        self.window_ladder = (tuple(ccfg.window_ladder) or
                              _ladder_from(serving.microbatch_window))
        self.depth_ladder = (tuple(ccfg.depth_ladder) or
                             _ladder_from(serving.queue_depth, floor=32))
        base_tol = server.engine.cfg.rwr_tol
        if base_tol > 0:
            self.tol_ladder: Tuple[float, ...] = tuple(
                sorted(set(ccfg.tol_ladder) | {base_tol}))
        else:
            # exact fixed-iteration sweeps configured: the tol knob is
            # disabled rather than silently switching the engine onto
            # the adaptive path (a semantics change, not a tuning)
            self.tol_ladder = (0.0,)
        self.window_idx = self._nearest(self.window_ladder, knobs.window)
        self.depth_idx = self._nearest(self.depth_ladder, knobs.queue_depth)
        self.tol_idx = self._nearest(self.tol_ladder, knobs.rwr_tol)
        # the configured baseline (episode starts return here; see
        # reset_knobs) — derived from the serving CONFIG, not the live
        # knobs: a controller may be constructed (e.g. restored from a
        # checkpoint) while the knobs sit mid-ladder
        self._baseline_idx = (
            self._nearest(self.window_ladder, serving.microbatch_window),
            self._nearest(self.depth_ladder, serving.queue_depth),
            self._nearest(self.tol_ladder, base_tol))
        # interval accounting (deltas between observations)
        self._last = {"good": 0, "viol": 0, "dropped": 0, "throttled": 0,
                      "evicted": 0, "rejected": 0, "sweeps": 0,
                      "events": 0, "batches": 0}
        self._events = 0
        self._batches = 0
        self._service_ema = 0.0

    @staticmethod
    def _nearest(ladder: Tuple, value) -> int:
        return int(np.argmin([abs(float(r) - float(value)) for r in ladder]))

    # -- per-batch accounting -------------------------------------------------

    def reset_knobs(self) -> None:
        """Return every knob to the serving-config baseline — called at
        episode starts so (a) training episodes all start from the same
        operating point and are comparable, and (b) a frozen evaluation
        run starts exactly where a static baseline config would, so its
        score difference is pure adaptivity, not a head start from
        wherever the previous episode happened to leave the knobs."""
        self.window_idx, self.depth_idx, self.tol_idx = self._baseline_idx
        self.apply(0)  # re-assert via a noop move

    def rebaseline(self) -> None:
        """Re-anchor the interval baseline at the CURRENT counter values —
        called at episode starts, where the caller may have reset the
        server (fresh telemetry) or the ledger between episodes and the
        stale baseline would fabricate a huge first-interval delta."""
        led, tel = self.ledger, self.server.telemetry
        self._last.update(
            good=led.n_good, viol=led.n_viol, dropped=tel.n_dropped,
            throttled=self._throttled(), evicted=tel.n_evicted,
            rejected=tel.n_rejected,
            sweeps=self.server.engine.rwr_sweeps,
            events=self._events, batches=self._batches)

    def note_batch(self, n_events: int, service_clock_s: float) -> None:
        """Called at every micro-batch boundary. ``service_clock_s`` is
        the executor's last device-step duration measured through the
        injected clock (0 under a ``VirtualClock`` — deterministic)."""
        self._events += n_events
        self._batches += 1
        self._service_ema = 0.8 * self._service_ema + 0.2 * service_clock_s

    # -- observation ----------------------------------------------------------

    def observation(self, now: float) -> np.ndarray:
        tel = self.server.telemetry
        queue = self.server.queue
        slo = max(self.ledger.slo_s, 1e-6)
        lag = self.ledger.lag(now, pending=len(queue))
        d_events = max(self._events - self._last["events"], 1)
        # counter resets (server.reset between episodes) can only lower
        # the raw counters; clamp so the obs stays in [0, 1] regardless
        d_evicted = max(tel.n_evicted - self._last["evicted"], 0)
        d_rejected = max(tel.n_rejected - self._last["rejected"], 0)
        sweeps = self.server.engine.rwr_sweeps
        d_batches = max(self._batches - self._last["batches"], 1)
        d_sweeps = max(sweeps - self._last["sweeps"], 0)
        sweep_cap = max(self.server.engine.cfg.rwr_iters, 1)
        p50 = lambda ch: tel.latency_percentile(50, ch)  # noqa: E731
        step_p50 = p50("stage_rwr") + p50("stage_gray") + p50("stage_merge")
        obs = np.array([
            len(queue) / max(self.knobs.queue_depth, 1),
            min(d_evicted / d_events, 1.0),
            min(d_rejected / d_events, 1.0),
            min(lag / slo, _LAG_CLIP) / _LAG_CLIP,
            min(self._service_ema / slo, _LAG_CLIP) / _LAG_CLIP,
            min(d_events / (d_batches * max(self.knobs.window, 1)), 1.0),
            min(d_sweeps / (d_batches * sweep_cap), 1.0),
            min(p50("stage_rwr") / max(step_p50, 1e-9), 1.0),
            min(p50("stage_merge") / max(step_p50, 1e-9), 1.0),
            self.window_idx / max(len(self.window_ladder) - 1, 1),
            self.depth_idx / max(len(self.depth_ladder) - 1, 1),
            self.tol_idx / max(len(self.tol_ladder) - 1, 1),
        ], np.float32)
        if self.ccfg.freshness_obs:
            if self.freshness is not None:
                stal, burn = self.freshness.worst(now)
            else:
                stal, burn = 0.0, 0.0
            obs = np.concatenate([obs, np.array([
                min(stal / slo, _LAG_CLIP) / _LAG_CLIP,
                min(max(burn, 0.0), 1.0),
            ], np.float32)])
        return obs

    # -- reward ---------------------------------------------------------------

    def _throttled(self) -> int:
        """Demand the closed-loop source's lag modulation held back so
        far (0 on open-loop runs, which have no source on the ledger)."""
        src = getattr(self.ledger, "closed_src", None)
        return int(src.n_throttled) if src is not None else 0

    def reward(self, mark: bool = True) -> float:
        """Goodput reward over the interval since the last call (module
        docstring); ``mark`` advances the interval baseline."""
        led, tel = self.ledger, self.server.telemetry
        thr = self._throttled()
        d_good = led.n_good - self._last["good"]
        d_viol = led.n_viol - self._last["viol"]
        d_drop = tel.n_dropped - self._last["dropped"]
        d_thr = max(thr - self._last["throttled"], 0)
        if mark:
            self._last.update(
                good=led.n_good, viol=led.n_viol, dropped=tel.n_dropped,
                throttled=thr, evicted=tel.n_evicted,
                rejected=tel.n_rejected,
                sweeps=self.server.engine.rwr_sweeps,
                events=self._events, batches=self._batches)
        denom = max(d_good + d_viol + d_drop + d_thr, 1)
        return float((d_good - self.ccfg.viol_weight * d_viol - d_drop
                      - d_thr) / denom)

    # -- actions --------------------------------------------------------------

    def apply(self, action: int) -> None:
        name = ACTION_NAMES[action]
        if name == "window_up":
            self.window_idx = min(self.window_idx + 1,
                                  len(self.window_ladder) - 1)
        elif name == "window_down":
            self.window_idx = max(self.window_idx - 1, 0)
        elif name == "depth_up":
            self.depth_idx = min(self.depth_idx + 1,
                                 len(self.depth_ladder) - 1)
        elif name == "depth_down":
            self.depth_idx = max(self.depth_idx - 1, 0)
        elif name == "tol_up":
            self.tol_idx = min(self.tol_idx + 1, len(self.tol_ladder) - 1)
        elif name == "tol_down":
            self.tol_idx = max(self.tol_idx - 1, 0)
        self.knobs.set_window(self.window_ladder[self.window_idx])
        self.knobs.set_queue_depth(self.depth_ladder[self.depth_idx])
        if self.tol_ladder != (0.0,):
            self.knobs.set_rwr_tol(self.tol_ladder[self.tol_idx])

    # -- persistence ----------------------------------------------------------

    def knob_state(self) -> Dict[str, int]:
        return {"window_idx": self.window_idx, "depth_idx": self.depth_idx,
                "tol_idx": self.tol_idx}

    def load_knob_state(self, sd: Dict[str, int]) -> None:
        self.window_idx = int(np.clip(int(sd["window_idx"]), 0,
                                      len(self.window_ladder) - 1))
        self.depth_idx = int(np.clip(int(sd["depth_idx"]), 0,
                                     len(self.depth_ladder) - 1))
        self.tol_idx = int(np.clip(int(sd["tol_idx"]), 0,
                                   len(self.tol_ladder) - 1))
        self.apply(0)  # re-assert the restored knob positions (noop move)
