"""EngineState + StepOutput — the functional core's explicit state.

The engine API is ``engine.step(state, update_batch) -> (state, StepOutput)``:
every quantity that evolves across serving steps and is *data* (device
arrays or plain counters) lives in :class:`EngineState` and is threaded
functionally — no facade owns a hidden copy of it. Host-side *caches* that
are pure functions of this state (the ELL mirror, the Louvain dendrogram,
the storm seed memo) live on the :class:`~repro.engine.core.Engine` and are
rebuilt on demand, so dropping them never changes results (DESIGN.md §4).
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, NamedTuple, Optional, Tuple

import jax.numpy as jnp

from repro.core.graph import DynamicGraph


@dataclass(frozen=True)
class EngineState:
    """One engine's evolving match state.

    graph        — the live dynamic graph (device pytree)
    r_lab        — warm-start label-RWR table of the FULL graph, kept by the
                   storm fallback (None until the first storm step)
    rlab_events  — update events applied since ``r_lab`` was refreshed (the
                   staleness key of the storm seed cache)
    rlab_version — bumped on every refresh (seed-memo identity key)
    step_idx     — serving steps taken
    """

    graph: DynamicGraph
    r_lab: Optional[jnp.ndarray] = None
    rlab_events: int = 0
    rlab_version: int = 0
    step_idx: int = 0

    def evolve(self, **kw) -> "EngineState":
        return replace(self, **kw)


class QueryDelta(NamedTuple):
    """Per-standing-query result of one engine step."""

    qid: str
    name: str
    n_new: int      # patterns first seen this step
    total: int      # live patterns in the store
    exact: int      # live exact patterns


class StepOutput(NamedTuple):
    """Everything one ``engine.step`` reports (facades project subsets)."""

    step: int
    elapsed: float            # matching-pipeline time (the paper's metric)
    n_recompute: int
    frac_affected: float
    community_size: int
    rl_loss: float
    storm: bool               # full-graph fallback taken this step
    subgraph_nodes: int
    subgraph_edges: int
    ell_refresh_s: float      # mirror maintenance (ELL cache and/or the
                              # edge-partition router), outside ``elapsed``
    n_pruned: int
    n_events: int             # masked update entries applied this step
    rlab_cache_hit: bool      # storm step reused r_lab without refreshing
    seed_cache_hit: bool      # storm step reused every bucket's seed top-k
    rwr_sweeps: int = 0       # label-RWR sweeps run (measured if adaptive)
    rwr_cols_skipped: int = 0  # converged-column sweeps retired (adaptive)
    deltas: Tuple[QueryDelta, ...] = ()
    # per-stage wall seconds (DESIGN.md §8) — None unless tracing is on;
    # keys: apply/ell_refresh/prune/pem/extract/rwr/seeds/gray/
    # device_wait/merge/feedback. The serving layer feeds these into
    # ``stage_*`` telemetry channels.
    stage_s: Optional[Dict[str, float]] = None

    @property
    def n_new_patterns(self) -> int:
        return sum(d.n_new for d in self.deltas)
