"""Host-side pattern stores — the engine's per-query result state.

:class:`PatternStore` dedups matched subgraphs by their (sorted) vertex
assignment; it is the only per-query piece of a serving step. Lives here
(not ``core.matcher``) because the engine owns it now; the matcher module
re-exports the names for the pre-engine import paths.

``to_arrays``/``from_arrays`` give the store an array codec so whole-engine
checkpoints (``Engine.save``/``load``) can round-trip it through
``repro.checkpoint`` next to the device state (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core.graph import DynamicGraph


class PatternStore:
    """Host-side dedup of matched subgraphs (keyed by the vertex assignment)."""

    def __init__(self):
        self._patterns: Dict[Tuple[int, ...], Tuple[float, bool]] = {}

    def share_from(self, primary: "PatternStore") -> None:
        """Become a shared view of ``primary``: both stores reference the
        SAME pattern dict. Exact-duplicate alias stores are bitwise clones
        by construction (one device row serves the whole group, and
        ``Engine._merge`` feeds every group member identical arrays), so
        sharing the dict makes the per-alias merge fan-out O(1) per group
        instead of O(aliases) — the measured bank1024 host cost (ROADMAP).
        A store silently un-shares if :meth:`load_arrays` later rebinds its
        dict; ``Engine`` re-shares content-equal group members after load.
        """
        self._patterns = primary._patterns

    def shares_with(self, other: "PatternStore") -> bool:
        return self._patterns is other._patterns

    def merge_arrays(self, matched: np.ndarray, goodness: np.ndarray,
                     exact: np.ndarray, valid: np.ndarray,
                     q_mask: np.ndarray) -> int:
        new = 0
        qm = np.asarray(q_mask)
        for i in range(matched.shape[0]):
            if not valid[i]:
                continue
            verts = matched[i][qm]
            if (verts < 0).any():
                continue
            key = tuple(sorted(int(v) for v in verts))
            if len(set(key)) != len(key):
                continue  # degenerate (data vertex reused)
            if key not in self._patterns:
                new += 1
                self._patterns[key] = (float(goodness[i]), bool(exact[i]))
            elif goodness[i] > self._patterns[key][0]:
                self._patterns[key] = (float(goodness[i]), bool(exact[i]))
        return new

    def merge(self, res, q_mask: np.ndarray) -> int:
        """Merge a single-query :class:`~repro.core.gray.GRayResult`."""
        return self.merge_arrays(np.asarray(res.matched),
                                 np.asarray(res.goodness),
                                 np.asarray(res.exact),
                                 np.asarray(res.valid), q_mask)

    def prune(self, node_mask: np.ndarray) -> int:
        """Drop patterns touching vertices no longer live.

        Later ``UpdateBatch``es can delete every arc of a matched vertex;
        without this hook ``n_patterns_total``/``n_exact_total`` drift upward
        on deletion-heavy streams. Invalidation is deliberately *vertex*-
        level: patterns are keyed by their vertex assignment and approximate
        matches never required the literal edge (bridges admit multi-hop
        paths), so removing a single matched arc does not falsify the
        pattern — a dead vertex does. Returns the number of patterns removed.
        """
        node_mask = np.asarray(node_mask, bool)
        dead = [key for key in self._patterns
                if any(not node_mask[v] for v in key)]
        for key in dead:
            del self._patterns[key]
        return len(dead)

    @property
    def total(self) -> int:
        return len(self._patterns)

    @property
    def exact(self) -> int:
        return sum(1 for _, e in self._patterns.values() if e)

    # -- checkpoint codec (Engine.save/load) ----------------------------------

    def to_arrays(self) -> Dict[str, np.ndarray]:
        """Store contents as fixed-dtype arrays (keys (N, L), N patterns of
        key length L — one query's patterns all share L)."""
        keys = sorted(self._patterns)
        length = len(keys[0]) if keys else 0
        return {
            "keys": np.asarray(keys, np.int64).reshape(len(keys), length),
            "goodness": np.asarray([self._patterns[k][0] for k in keys],
                                   np.float32),
            "exact": np.asarray([self._patterns[k][1] for k in keys], bool),
        }

    def load_arrays(self, arrays: Dict[str, np.ndarray]) -> None:
        self._patterns = {
            tuple(int(v) for v in key): (float(gd), bool(ex))
            for key, gd, ex in zip(arrays["keys"], arrays["goodness"],
                                   arrays["exact"])}


def live_vertex_mask(g: DynamicGraph) -> np.ndarray:
    """Vertices incident to at least one live arc (host-side)."""
    em = np.asarray(g.edge_mask)
    live = np.zeros(g.n_max, bool)
    live[np.asarray(g.senders)[em]] = True
    live[np.asarray(g.receivers)[em]] = True
    return live & np.asarray(g.node_mask)
