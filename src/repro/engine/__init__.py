"""Functional-core match engine (DESIGN.md §4).

One :class:`Engine` owns the single step pipeline every matcher facade
drives, a registry of standing queries in bucketed dynamic banks, and
whole-engine checkpointing. ``engine.step(state, upd)`` threads an explicit
:class:`EngineState`; facades (`core.matcher`, `serving.server`) only
project its :class:`StepOutput` into their historical stats types.
"""

from repro.engine.buckets import QueryBucket, bucket_shape
from repro.engine.core import Engine, engine_step
from repro.engine.sharding import (ShardedBankMatch, ShardedSweep,
                                   device_split, graph_shard_count,
                                   query_shard_count)
from repro.engine.state import EngineState, QueryDelta, StepOutput
from repro.engine.store import PatternStore, live_vertex_mask

__all__ = [
    "Engine", "engine_step", "EngineState", "StepOutput", "QueryDelta",
    "QueryBucket", "bucket_shape", "ShardedBankMatch", "ShardedSweep",
    "device_split", "graph_shard_count", "query_shard_count",
    "PatternStore", "live_vertex_mask",
]
