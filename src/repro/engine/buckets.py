"""Bucketed dynamic query banks (DESIGN.md §4).

Standing queries are grouped into *buckets* keyed on the padded shape
``(q_max, qe_max, B_pad)`` — pow-2 roundups of (query vertices, schedule
length, row count). Each bucket owns one padded :class:`QueryBank` and ONE
:class:`~repro.core.gray.BankGRayMatcher` compiled in the content-
independent ``memo=False`` mode, where every bank tensor is a jit
*argument* and the unroll structure depends only on the bucket key. That
is what makes membership dynamic: ``register`` writes a query's tensors
into a free row and ``retire`` zeroes them — device scatters, never a
retrace. Only outgrowing ``B_pad`` (a doubling) builds a new bucket.

Execution is vmapped over the row axis on one device and ``shard_map``-ed
over it when more devices are visible (rows are independent in
``memo=False`` mode, so the sharded program needs no collectives and its
results are bit-identical to the vmap path — pinned in
``tests/test_engine_sharding.py``).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.config.base import EngineConfig, IGPMConfig
from repro.core.graph import DynamicGraph, PartitionedEdges
from repro.core.gray import BankGRayMatcher, GRayResult
from repro.core.query import (PlanDAG, Query, QueryBank, SubPatternKey,
                              decompose, schedule_reads, stack_queries)
from repro.engine.sharding import ShardedBankMatch, query_shard_count
from repro.sparse.ell import EllGraph


def _pow2(x: int, floor: int) -> int:
    return max(floor, 1 << int(np.ceil(np.log2(max(x, 1)))))


def encode_strings(strs) -> np.ndarray:
    """Serialize strings as a flat ``uint8`` array (the checkpointer only
    carries numeric dtypes — unicode arrays would be cast to float32)."""
    return np.frombuffer("\n".join(strs).encode("utf-8"),
                         np.uint8).copy()


def decode_strings(a: np.ndarray) -> Tuple[str, ...]:
    if a.size == 0:
        return ()
    return tuple(bytes(np.asarray(a, np.uint8)).decode("utf-8").split("\n"))


def bucket_shape(query: Query, ecfg: EngineConfig) -> Tuple[int, int]:
    """The (q_max, qe_max) bucket a query pads into."""
    q = _pow2(query.n_nodes, ecfg.q_floor)
    qe = _pow2(query.n_edges, ecfg.qe_floor)
    if query.n_nodes > ecfg.q_cap or query.n_edges > ecfg.qe_cap:
        raise ValueError(
            f"query {query.name!r} ({query.n_nodes} vertices, "
            f"{query.n_edges} schedule edges) exceeds the engine caps "
            f"(q_cap={ecfg.q_cap}, qe_cap={ecfg.qe_cap})")
    return min(q, ecfg.q_cap), min(qe, ecfg.qe_cap)


def _empty_bank(q_max: int, qe_max: int, b_pad: int) -> QueryBank:
    return QueryBank(
        labels=jnp.zeros((b_pad, q_max), jnp.int32),
        mask=jnp.zeros((b_pad, q_max), bool),
        order_src=jnp.zeros((b_pad, qe_max), jnp.int32),
        order_dst=jnp.zeros((b_pad, qe_max), jnp.int32),
        order_tree=jnp.zeros((b_pad, qe_max), bool),
        order_mask=jnp.zeros((b_pad, qe_max), bool),
        anchor=jnp.zeros((b_pad,), jnp.int32),
        names=())


class QueryBucket:
    """One padded bank of standing queries sharing a jit signature.

    ``g_shards > 1`` adds the graph mesh axis: the storm/batch full-graph
    match runs on a 2-D ``(q, g)`` mesh against the shard-local ELL
    row-block mirror (``match(..., graph_sharded=True)``), while the
    induced-subgraph path keeps the graph replicated. ``q_budget`` caps
    the query-axis device share (the engine hands each axis its split)."""

    def __init__(self, cfg: IGPMConfig, q_max: int, qe_max: int, b_pad: int,
                 shard: str = "auto", g_shards: int = 1,
                 q_budget: Optional[int] = None,
                 node_cap: Optional[int] = None):
        self.q_max, self.qe_max, self.b_pad = q_max, qe_max, b_pad
        # sub-pattern DAG capacity: defaults to the identity bound (every
        # row needs ≤ q_max nodes, so q_max·b_pad never overflows); the
        # engine passes tighter pow-2 caps and grows them on DagFull
        self.node_cap = node_cap if node_cap is not None else q_max * b_pad
        self.dag = PlanDAG(self.node_cap)
        self.row_node = jnp.zeros((b_pad, qe_max), jnp.int32)
        self._row_keys: List[Optional[List[SubPatternKey]]] = [None] * b_pad
        self.bank = _empty_bank(q_max, qe_max, b_pad)
        self.matcher = BankGRayMatcher(
            self.bank, cfg.n_labels, cfg.top_k_patterns,
            rwr_iters=cfg.rwr_iters, restart=cfg.restart_prob,
            bridge_hops=cfg.bridge_hops, backend=cfg.backend,
            ell_width=cfg.ell_width, memo=False, rwr_tol=cfg.rwr_tol,
            node_cap=self.node_cap)
        self.n_shards = query_shard_count(b_pad, shard,
                                          max_devices=q_budget)
        self.g_shards = g_shards
        self._sharded = (
            ShardedBankMatch(self.matcher, self.n_shards, g_shards)
            if self.n_shards > 1 or g_shards > 1 else None)
        self.qids: List[Optional[str]] = [None] * b_pad
        self._queries: List[Optional[Query]] = [None] * b_pad
        self._row_masks: List[Optional[np.ndarray]] = [None] * b_pad
        self._names: List[str] = [f"q{i}" for i in range(b_pad)]
        self.version = 0  # bumped on every membership change (seed memo key)

    # -- membership -----------------------------------------------------------

    @property
    def key(self) -> Tuple[int, int, int]:
        return (self.q_max, self.qe_max, self.b_pad)

    @property
    def dag_key(self) -> Tuple[int, int, int, int]:
        """Bucket identity including the DAG node capacity — what the
        compiled trace is keyed on (DESIGN.md §7)."""
        return (self.q_max, self.qe_max, self.b_pad, self.node_cap)

    @property
    def n_live(self) -> int:
        return sum(q is not None for q in self.qids)

    @property
    def full(self) -> bool:
        return self.n_live == self.b_pad

    def rows(self) -> List[Tuple[int, str]]:
        """(slot, qid) of every occupied row, slot order."""
        return [(i, q) for i, q in enumerate(self.qids) if q is not None]

    def query(self, slot: int) -> Query:
        q = self._queries[slot]
        assert q is not None
        return q

    def row_mask(self, slot: int) -> np.ndarray:
        m = self._row_masks[slot]
        assert m is not None
        return m

    def register(self, qid: str, query: Query) -> int:
        """Write ``query`` into a free row; returns the slot. Device-array
        row writes only — the bucket's compiled programs are untouched.
        The query's sub-pattern path is interned into the bucket DAG
        (refcount increments; raises :exc:`~repro.core.query.DagFull`
        before touching anything when the capacity is exhausted) and the
        row's ``row_node`` plan is scattered alongside the bank row."""
        slot = self.qids.index(None)  # raises ValueError when full
        row = stack_queries([query], q_max=self.q_max, qe_max=self.qe_max)
        row_q = row.query(0)
        keys = decompose(row_q)
        reads = schedule_reads(row_q)
        slots = self.dag.acquire(keys)  # may raise DagFull — no mutation yet
        plan = np.zeros(self.qe_max, np.int32)
        for ei in range(row_q.n_edges):
            plan[ei] = slots[reads[ei]]
        self.row_node = self.row_node.at[slot].set(jnp.asarray(plan))
        self._row_keys[slot] = keys
        b = self.bank
        self._names[slot] = query.name
        self.bank = b._replace(
            labels=b.labels.at[slot].set(row.labels[0]),
            mask=b.mask.at[slot].set(row.mask[0]),
            order_src=b.order_src.at[slot].set(row.order_src[0]),
            order_dst=b.order_dst.at[slot].set(row.order_dst[0]),
            order_tree=b.order_tree.at[slot].set(row.order_tree[0]),
            order_mask=b.order_mask.at[slot].set(row.order_mask[0]),
            anchor=b.anchor.at[slot].set(row.anchor[0]),
            names=tuple(self._names))
        self.qids[slot] = qid
        self._queries[slot] = query
        self._row_masks[slot] = np.asarray(row.mask[0])
        self.version += 1
        return slot

    def retire(self, qid: str) -> int:
        """Zero the row of ``qid``; returns the freed slot. The row's DAG
        refcounts decrement, freeing node slots whose last holder left."""
        slot = self.qids.index(qid)
        keys = self._row_keys[slot]
        assert keys is not None
        self.dag.release(keys)
        self._row_keys[slot] = None
        self.row_node = self.row_node.at[slot].set(0)
        b = self.bank
        self._names[slot] = f"q{slot}"
        self.bank = b._replace(
            labels=b.labels.at[slot].set(0),
            mask=b.mask.at[slot].set(False),
            order_src=b.order_src.at[slot].set(0),
            order_dst=b.order_dst.at[slot].set(0),
            order_tree=b.order_tree.at[slot].set(False),
            order_mask=b.order_mask.at[slot].set(False),
            anchor=b.anchor.at[slot].set(0),
            names=tuple(self._names))
        self.qids[slot] = None
        self._queries[slot] = None
        self._row_masks[slot] = None
        self.version += 1
        return slot

    def rename_row(self, old_qid: str, new_qid: str, query: Query) -> int:
        """Hand ``old_qid``'s row to an exact-duplicate alias — pure host
        bookkeeping (the device row is bitwise the alias's row already),
        so the seed memo and compiled traces stay valid. Returns the
        slot."""
        slot = self.qids.index(old_qid)
        self.qids[slot] = new_qid
        self._queries[slot] = query
        self._names[slot] = query.name
        self.bank = self.bank._replace(names=tuple(self._names))
        return slot

    # -- execution ------------------------------------------------------------

    def seeds(self, g: DynamicGraph, r_lab: jnp.ndarray,
              seed_filter: Optional[jnp.ndarray]
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return self.matcher.seeds(g, r_lab, seed_filter, bank=self.bank)

    def match(self, g: DynamicGraph, r_lab: jnp.ndarray,
              seed_filter: Optional[jnp.ndarray] = None,
              ell: Optional[EllGraph] = None,
              seeds: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              graph_sharded: bool = False,
              part: Optional[PartitionedEdges] = None) -> GRayResult:
        """Match every row against ``g`` — vmap on one device, shard_map
        over the mesh otherwise. ``seeds`` short-circuits the top-k
        (the storm seed cache path). ``graph_sharded`` marks a full-graph
        call whose ``ell`` is the shard-local row-block mirror (the graph
        axis engages; only meaningful when the bucket has ``g_shards >
        1``). ``part`` is the receiver-sliced COO edge store (partitioned
        storage, DESIGN.md §10) — it replaces the graph's edge arrays on
        the mesh and requires ``graph_sharded=True``."""
        if seeds is None:
            seeds = self.seeds(g, r_lab, seed_filter)
        seed_ids, seed_mask = seeds
        if self._sharded is not None:
            return self._sharded(g, r_lab, seed_ids, seed_mask, ell,
                                 self.bank, graph_sharded=graph_sharded,
                                 row_node=self.row_node, part=part)
        assert part is None, "partitioned storage needs the graph mesh"
        return self.matcher.match_from_seeds(g, r_lab, seed_ids, seed_mask,
                                             ell=ell, bank=self.bank,
                                             row_node=self.row_node)

    def trace_count(self) -> int:
        """Compiled-trace count across this bucket's jitted programs."""
        n = 0
        for fn in (self.matcher._match, self.matcher._seeds):
            size = getattr(fn, "_cache_size", None)
            n += size() if size is not None else 0
        if self._sharded is not None:
            n += self._sharded.trace_count()
        return n

    # -- checkpoint views ------------------------------------------------------

    def bank_arrays(self) -> Dict[str, np.ndarray]:
        b = self.bank
        return {
            "labels": np.asarray(b.labels), "mask": np.asarray(b.mask),
            "order_src": np.asarray(b.order_src),
            "order_dst": np.asarray(b.order_dst),
            "order_tree": np.asarray(b.order_tree),
            "order_mask": np.asarray(b.order_mask),
            "anchor": np.asarray(b.anchor),
            "occupancy": np.asarray([q is not None for q in self.qids]),
            # host metadata rides along as uint8/int64 (the checkpointer
            # carries numeric dtypes only): the per-row names the bank
            # previously dropped, the row→node plan, and the DAG digest
            # (per-slot key hash + refcount) for the round-trip check
            "names": encode_strings(self._names),
            "row_node": np.asarray(self.row_node),
            "dag": self.dag.digest(),
        }

    def load_bank_arrays(self, arrays: Dict[str, np.ndarray]) -> None:
        occ = np.asarray(arrays["occupancy"], bool)
        live = np.asarray([q is not None for q in self.qids])
        if not np.array_equal(occ, live):
            raise ValueError(
                "checkpointed bucket occupancy does not match the live "
                "registry — register the same queries before load()")
        # the DAG/plans are rebuilt by registration, but SLOT ids depend on
        # the register/retire history (freed slots are reused lowest-first),
        # which a restore does not replay — so verify up to slot
        # permutation: the live DAG must hold the same (key-hash, refcount)
        # multiset, and every row's plan must route through the same KEYS
        # (slot→hash mapped), even if the slot numbers moved
        if "dag" in arrays:
            ck_dag = np.asarray(arrays["dag"])
            lv_dag = self.dag.digest()
            ck_live = ck_dag[ck_dag[:, 1] > 0]
            lv_live = lv_dag[lv_dag[:, 1] > 0]
            if ck_live.shape != lv_live.shape or not np.array_equal(
                    ck_live[np.lexsort(ck_live.T[::-1])],
                    lv_live[np.lexsort(lv_live.T[::-1])]):
                raise ValueError(
                    "checkpointed sub-pattern DAG does not match the live "
                    "registry — register the same queries before load()")
            if "row_node" in arrays:
                rmask = occ[:, None] & np.asarray(self.bank.order_mask, bool)
                ck_h = ck_dag[:, 0][np.asarray(arrays["row_node"])]
                lv_h = lv_dag[:, 0][np.asarray(self.row_node)]
                if not np.array_equal(ck_h[rmask], lv_h[rmask]):
                    raise ValueError(
                        "checkpointed row→node plan does not match the "
                        "live bank")
        if "names" in arrays:
            names = decode_strings(np.asarray(arrays["names"]))
            if len(names) == self.b_pad:
                self._names = list(names)
        self.bank = self.bank._replace(
            names=tuple(self._names),
            **{f: jnp.asarray(arrays[f])
               for f in ("labels", "mask", "order_src", "order_dst",
                         "order_tree", "order_mask", "anchor")})
        self.version += 1
