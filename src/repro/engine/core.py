"""The match engine — ONE step pipeline behind every matcher facade.

``engine_step(engine, state, upd)`` is the only place in the repo that
sequences the paper's serving step:

  1. ``apply_update`` + incremental ELL-mirror refresh (one graph state)
  2. pattern-store pruning when removals could have killed a matched vertex
  3. PEM recompute mask (one Louvain cut, one DQN-controlled threshold)
  4. induced-subgraph extraction — or the full-graph *storm* fallback with
     warm-started label RWR and the staleness-keyed seed cache
  5. the label-conditioned RWR table (query-independent, shared by all
     buckets)
  6. one bank G-Ray match per bucket (vmap or shard_map over the row axis)
  7. host-side merge into per-query :class:`~repro.engine.store.PatternStore`

``BatchMatcher`` / ``NaiveIncrementalMatcher`` / ``AdaptiveMatcher`` /
``MatchServer`` are thin facades projecting :class:`StepOutput` into their
historical stats types; none of them owns a pipeline anymore (DESIGN.md §4).
The functional core is explicit: all evolving data rides in
:class:`~repro.engine.state.EngineState`; the Engine object holds the
registry (buckets, stores), jit caches, and host-side caches that are pure
functions of the state (ELL mirror, Louvain dendrogram, storm seed memo).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.config.base import EngineConfig, IGPMConfig, resolve_backend
from repro.core.graph import (DynamicGraph, EdgePartition, EllCache,
                              UpdateBatch, apply_update, updated_vertices)
from repro.core.pem import PartialExecutionManager
from repro.core.query import DagFull, Query, query_signature
from repro.core.rwr import label_rwr, label_rwr_adaptive
from repro.core.subgraph import extract_induced, remap_matched
from repro.engine.buckets import (QueryBucket, _pow2, bucket_shape,
                                  decode_strings, encode_strings)
from repro.engine.sharding import ShardedSweep, device_split
from repro.engine.state import EngineState, QueryDelta, StepOutput
from repro.engine.store import PatternStore, live_vertex_mask
from repro.obs import Obs


class Engine:
    """Functional-core match engine with bucketed dynamic query banks."""

    def __init__(self, cfg: IGPMConfig, ecfg: Optional[EngineConfig] = None,
                 seed: int = 0):
        ecfg = ecfg or EngineConfig()
        if ecfg.mode not in ("incremental", "batch"):
            raise ValueError(f"unknown engine mode {ecfg.mode!r}")
        if cfg.backend == "auto":
            cfg = dataclasses.replace(cfg,
                                      backend=resolve_backend(cfg.backend))
        self.cfg = cfg
        self.ecfg = ecfg
        self.seed = seed
        self.pem: Optional[PartialExecutionManager] = (
            None if ecfg.mode == "batch"
            else PartialExecutionManager(cfg, adaptive=ecfg.adaptive,
                                         seed=seed))
        # graph mesh axis: how the visible devices split between the query
        # and graph axes (DESIGN.md §5); 1/1 on a single device
        self.q_budget, self.g_shards = device_split(
            ecfg.shard, ecfg.graph_shard, cfg.n_max)
        self._sweeps = (ShardedSweep(self.g_shards)
                        if self.g_shards > 1 else None)
        # edge-partitioned storage (DESIGN.md §10): co-partition the edge
        # arrays with the receiver slices so each device holds ~1/g of the
        # store. Host router (EdgePartition / partitioned EllCache) keeps
        # the slices fresh; the mesh then never sees replicated edges.
        if ecfg.edge_partition not in ("off", "on"):
            raise ValueError(
                f"unknown edge_partition policy {ecfg.edge_partition!r}")
        self.partitioned = (ecfg.edge_partition == "on"
                            and self.g_shards > 1)
        self.ell_cache = (EllCache(cfg.n_max, cfg.e_max, cfg.ell_width,
                                   n_shards=self.g_shards,
                                   partitioned=self.partitioned,
                                   headroom=ecfg.partition_headroom)
                          if cfg.backend == "ell" else None)
        self.part_cache = (EdgePartition(cfg.n_max, cfg.e_max, self.g_shards,
                                         headroom=ecfg.partition_headroom)
                           if self.partitioned and cfg.backend == "coo"
                           else None)
        # per-bucket match fan-out pool (installed by the serving runtime
        # when RuntimeConfig.n_executors > 1; None = serial dispatch)
        self._exec_pool = None
        # XLA collectives carry no cross-launch ordering: two threads
        # launching shard_map programs over the same device set interleave
        # their all_gather rendezvous and deadlock. When the graph mesh has
        # collectives (g_shards > 1) pooled workers serialize device
        # dispatch through this lock; single-device meshes skip it.
        self._dispatch_lock = threading.Lock()
        self.buckets: Dict[Tuple[int, int], QueryBucket] = {}
        self.stores: Dict[str, PatternStore] = {}
        self._where: Dict[str, Tuple[int, int]] = {}  # qid → bucket (q, qe)
        self._order: List[str] = []                   # registration order
        # exact-duplicate groups: content signature → [primary, *aliases].
        # The primary owns the bank row; aliases ride it for free (zero
        # device work at register; results fan out to every store).
        self._dups: Dict[Tuple, List[str]] = {}
        self._sig_of: Dict[str, Tuple] = {}
        self._alias_query: Dict[str, Query] = {}      # alias qid → its Query
        self.n_dedup = 0
        # storm seed cache (satellite: consecutive storm steps stop paying
        # the full-graph seed recompute) — see EngineConfig. Entries are
        # (version key, recompute mask, seeds): a step reuses the seeds
        # when the versions match and its mask is within
        # ``seed_cache_hamming`` flips of the cached one (0 = exact).
        self._seed_memo: Dict[Tuple[int, int],
                              Tuple[tuple, np.ndarray, tuple]] = {}
        self.rlab_hits = 0
        self.rlab_misses = 0
        self.seed_hits = 0
        self.seed_hits_exact = 0
        self.seed_hits_bounded = 0
        self.seed_misses = 0
        self.rwr_sweeps = 0  # label-RWR sweeps actually run (adaptive)
        self.rwr_cols_skipped = 0  # converged-column sweeps retired
        self._last_sweeps = 0
        self._last_cols_skipped = 0
        # observability hub (DESIGN.md §8): the serving/runtime layers
        # reuse this engine's hub so one event stream spans all threads
        self.obs = Obs(ecfg.obs)
        # last _merge fan-out shape (bank rows folded / alias stores
        # written) — the host-cost suspect ROADMAP tracks
        self.last_merge_rows = 0
        self.last_merge_stores = 0
        self.last_merge_folds = 0
        # optional serving-controller attachment (repro.control): when a
        # runtime binds one here, its state rides the engine checkpoint so
        # save/load round-trips the learned scheduling policy too
        self.control = None

    # -- standing-query registry ----------------------------------------------

    def register(self, query: Query, qid: Optional[str] = None) -> str:
        """Add a standing query; returns its id. Inside an existing bucket
        this is a device row write (zero recompilations); a new padded
        shape — or outgrowing ``B_pad`` — builds a new bucket."""
        if qid is None:
            qid = query.name
            i = 1
            while qid in self.stores:
                qid = f"{query.name}#{i}"
                i += 1
        elif qid in self.stores:
            raise ValueError(f"qid {qid!r} already registered")
        shape = bucket_shape(query, self.ecfg)
        # with dedup disabled every registration is its own singleton group
        # (duplicates occupy real rows and must not share result fan-out)
        sig = query_signature(query) if self.ecfg.dedup else (qid,)
        if self.ecfg.dedup and self._dups.get(sig):
            # exact-duplicate fast path: the query's tensors are bitwise a
            # live row already — alias that row. ZERO device work (no bank
            # write, no DAG change, no seed-memo invalidation); the row's
            # match results fan out to this store too (DESIGN.md §7).
            self._dups[sig].append(qid)
            self._sig_of[qid] = sig
            self._alias_query[qid] = query
            self.n_dedup += 1
            store = PatternStore()
            primary = self.stores[self._dups[sig][0]]
            if primary.total == 0:
                # alias stores are bitwise clones of the primary from here
                # on (identical merge inputs per group), so share the dict:
                # _merge then folds each row ONCE per group, not per alias
                store.share_from(primary)
            self.stores[qid] = store
            self._where[qid] = shape
            self._order.append(qid)
            self.obs.instant("bank/register_alias", qid=qid,
                             primary=self._dups[sig][0])
            return qid
        bucket = self.buckets.get(shape)
        if bucket is None:
            bucket = QueryBucket(self.cfg, *shape, b_pad=1,
                                 shard=self.ecfg.shard,
                                 g_shards=self.g_shards,
                                 q_budget=self.q_budget,
                                 node_cap=shape[0])
            self.buckets[shape] = bucket
        elif bucket.full:
            bucket = self._grow(bucket)
        with self.obs.span("bank/register", qid=qid,
                           bucket=f"{shape[0]}x{shape[1]}"):
            while True:
                try:
                    bucket.register(qid, query)
                    break
                except DagFull:
                    # sub-pattern capacity outgrown: double it (a rebuild,
                    # the same amortized cost as the B_pad doubling)
                    bucket = self._rebuild(bucket, bucket.b_pad,
                                           node_cap=2 * bucket.node_cap)
        self._dups.setdefault(sig, []).append(qid)
        self._sig_of[qid] = sig
        self._seed_memo.pop(shape, None)
        self.stores[qid] = PatternStore()
        self._where[qid] = shape
        self._order.append(qid)
        return qid

    def retire(self, qid: str) -> None:
        """Drop a standing query (device row clear — zero recompilations).
        Its pattern store goes with it. Retiring an ALIAS (or a primary
        with live aliases, which hands its row to the next one) is pure
        host bookkeeping. A bucket left EMPTY is dropped outright (no
        reason to keep sweeping a dead bank); one left at ≤ quarter
        occupancy compacts to half its row capacity (the shrink mirror of
        the growth doubling, so churn-heavy servers stop sweeping dead
        rows; amortized exactly like the doubling)."""
        if qid not in self._where:
            raise KeyError(f"unknown qid {qid!r}; live: {self._order}")
        with self.obs.span("bank/retire", qid=qid):
            shape = self._where.pop(qid)
            sig = self._sig_of.pop(qid)
            group = self._dups[sig]
            del self.stores[qid]
            self._order.remove(qid)
            bucket = self.buckets[shape]
            if qid != group[0]:
                # alias — the primary keeps the row
                group.remove(qid)
                del self._alias_query[qid]
                return
            group.pop(0)
            if group:
                # primary with aliases: promote the next one onto the row
                # (bitwise the same tensors, so the device bank — and the
                # seed memo — stay untouched)
                promoted = group[0]
                bucket.rename_row(qid, promoted,
                                  self._alias_query.pop(promoted))
                return
            del self._dups[sig]
            bucket.retire(qid)
            self._seed_memo.pop(shape, None)
            if bucket.n_live == 0:
                del self.buckets[shape]
            elif bucket.b_pad > 1 and bucket.n_live <= bucket.b_pad // 4:
                self._rebuild(bucket, bucket.b_pad // 2)

    def _reshare_alias_stores(self) -> None:
        """Re-establish pattern-dict sharing across exact-duplicate groups
        whose stores hold equal content (fresh after ``reset``, or loaded
        per-qid by ``load`` — ``PatternStore.load_arrays`` rebinds each
        store's dict, silently un-sharing it). Group members that diverged
        (late aliases registered after the primary accumulated patterns)
        stay private, which preserves their per-store semantics."""
        for group in self._dups.values():
            primary = self.stores.get(group[0])
            if primary is None:
                continue
            for alias in group[1:]:
                store = self.stores.get(alias)
                if (store is not None and not store.shares_with(primary)
                        and store._patterns == primary._patterns):
                    store.share_from(primary)

    def _rebuild(self, bucket: QueryBucket, b_pad: int,
                 node_cap: Optional[int] = None) -> QueryBucket:
        """Repack a bucket's live rows into a ``b_pad``-row bank — the one
        membership change that recompiles, by design. ``_grow`` doubles a
        full bucket; ``retire`` halves one at ≤ quarter occupancy (the
        ≤1/4 ↔ ×2 hysteresis keeps both amortized O(1) per change). The
        DAG capacity re-fits to the live distinct nodes unless an explicit
        ``node_cap`` is forced (the DagFull doubling)."""
        if node_cap is None:
            node_cap = _pow2(bucket.dag.n_nodes, bucket.q_max)
        with self.obs.span("bank/rebuild", b_pad=b_pad, node_cap=node_cap,
                           rows=bucket.n_live):
            fresh = QueryBucket(self.cfg, bucket.q_max, bucket.qe_max,
                                b_pad=b_pad, shard=self.ecfg.shard,
                                g_shards=self.g_shards,
                                q_budget=self.q_budget, node_cap=node_cap)
            for slot, qid in bucket.rows():
                fresh.register(qid, bucket.query(slot))
            self.buckets[(bucket.q_max, bucket.qe_max)] = fresh
        return fresh

    def _grow(self, bucket: QueryBucket) -> QueryBucket:
        # headroom for the incoming row (≤ q_max fresh nodes), so a grow
        # is ONE rebuild, not a rebuild plus a DagFull retry
        return self._rebuild(
            bucket, 2 * bucket.b_pad,
            node_cap=_pow2(bucket.dag.n_nodes + bucket.q_max, bucket.q_max))

    def query(self, qid: str) -> Query:
        q = self._alias_query.get(qid)
        if q is not None:
            return q
        shape = self._where[qid]
        bucket = self.buckets[shape]
        return bucket.query(bucket.qids.index(qid))

    @property
    def qids(self) -> Tuple[str, ...]:
        return tuple(self._order)

    def alias_groups(self) -> Dict[str, str]:
        """qid → its frontier-group primary (itself unless an alias) for
        every live standing query. Exact-duplicate group members share
        one device row and receive identical result fan-out each step,
        so any per-query delivery frontier is shared across the group —
        the FreshnessLedger (DESIGN.md §11) consumes this map."""
        return {qid: group[0]
                for group in self._dups.values() for qid in group}

    def partition_occupancy(self) -> Optional[float]:
        """Worst live-slice fill fraction of the edge-partitioned storage
        (DESIGN.md §10), or None when storage is not partitioned. This is
        overflow *proximity*: 1.0 means the next uneven batch can raise
        ``PartitionOverflowError`` — the health watchdog degrades before
        that."""
        if self.part_cache is not None:
            return self.part_cache.occupancy()
        if self.ell_cache is not None and self.partitioned:
            return self.ell_cache.occupancy()
        return None

    def occupancy(self) -> Dict[Tuple[int, int, int], Tuple[int, int]]:
        """bucket key (q_max, qe_max, B_pad) → (live rows, padded rows)."""
        return {b.key: (b.n_live, b.b_pad) for b in self.buckets.values()}

    def dag_occupancy(self) -> Dict[Tuple[int, int, int, int],
                                    Tuple[int, int]]:
        """DAG bucket key (q_max, qe_max, B_pad, node_cap) → (live
        sub-pattern nodes, node capacity) — the shared-table view of
        :meth:`occupancy` (DESIGN.md §7)."""
        return {b.dag_key: (b.dag.n_nodes, b.node_cap)
                for b in self.buckets.values()}

    def trace_count(self) -> int:
        """Total compiled traces across bucket programs — the membership
        tests pin this flat across register/retire inside a bucket."""
        return sum(b.trace_count() for b in self.buckets.values())

    def counters(self) -> Dict[str, int]:
        return {"rlab_cache_hits": self.rlab_hits,
                "rlab_cache_misses": self.rlab_misses,
                "seed_cache_hits": self.seed_hits,
                "seed_cache_hits_exact": self.seed_hits_exact,
                "seed_cache_hits_bounded": self.seed_hits_bounded,
                "seed_cache_misses": self.seed_misses,
                "rwr_sweeps": self.rwr_sweeps,
                "rwr_cols_skipped": self.rwr_cols_skipped,
                # shared sub-pattern occupancy (DESIGN.md §7): how many
                # standing queries the bank serves vs the device rows and
                # distinct DAG nodes actually paying for them
                "n_dedup": self.n_dedup,
                "standing_queries": len(self._order),
                "bank_rows": sum(b.n_live for b in self.buckets.values()),
                "dag_nodes": sum(b.dag.n_nodes
                                 for b in self.buckets.values()),
                "dag_node_cap": sum(b.node_cap
                                    for b in self.buckets.values())}

    # -- state lifecycle -------------------------------------------------------

    def init_state(self, graph: DynamicGraph) -> EngineState:
        return EngineState(graph=graph)

    def reset(self) -> None:
        """Clear accumulated match state but KEEP jit caches (and the PEM's
        learned threshold/policy) — benchmark warm/measure passes replay
        identical streams on one engine."""
        self.stores = {qid: PatternStore() for qid in self._order}
        self._reshare_alias_stores()
        self._seed_memo.clear()
        self.rlab_hits = self.rlab_misses = 0
        self.seed_hits = self.seed_misses = 0
        self.seed_hits_exact = self.seed_hits_bounded = 0
        self.rwr_sweeps = 0
        self.rwr_cols_skipped = 0
        if self.ell_cache is not None:
            self.ell_cache = EllCache(self.cfg.n_max, self.cfg.e_max,
                                      self.cfg.ell_width,
                                      n_shards=self.g_shards,
                                      partitioned=self.partitioned,
                                      headroom=self.ecfg.partition_headroom)
        if self.part_cache is not None:
            self.part_cache = EdgePartition(self.cfg.n_max, self.cfg.e_max,
                                            self.g_shards,
                                            headroom=self.ecfg
                                            .partition_headroom)

    # -- the ONE step pipeline -------------------------------------------------

    def step(self, state: EngineState,
             upd: UpdateBatch) -> Tuple[EngineState, StepOutput]:
        return engine_step(self, state, upd)

    def _apply(self, g: DynamicGraph,
               upd: UpdateBatch) -> Tuple[DynamicGraph, float]:
        """Apply the update, refreshing whichever mirror is carried (the
        ELL cache and/or the edge-partition router — DESIGN.md §10).

        The returned refresh time covers only the mirror maintenance — the
        COO ``apply_update`` is paid identically by all backends."""
        mirrors = [m for m in (self.ell_cache, self.part_cache)
                   if m is not None]
        if not mirrors:
            return apply_update(g, upd), 0.0
        for m in mirrors:
            if m._last is not g:
                m.rebuild(g)
        g2 = apply_update(g, upd)
        t0 = time.perf_counter()
        for m in mirrors:
            m.refresh(g, g2, upd)
        jax.block_until_ready(self.ell_cache._cols_d
                              if self.ell_cache is not None
                              else self.part_cache._send_d)
        return g2, time.perf_counter() - t0

    @property
    def _full_ell(self):
        return None if self.ell_cache is None else self.ell_cache.ell

    @property
    def _full_part(self):
        """The receiver-sliced edge partition to hand the graph mesh, or
        None when edge partitioning is off / the ELL backend carries the
        slices itself (its mirror is already built per receiver block)."""
        return None if self.part_cache is None else self.part_cache.part

    def _node_view(self, g: DynamicGraph) -> DynamicGraph:
        """``g`` with the replicated COO edge arrays stubbed to width-1
        placeholders. Partitioned mesh programs read only the node-level
        fields (labels/node_mask/degree) plus the PartitionedEdges slices,
        so shipping this view keeps replicated edge storage off the mesh
        — the whole point of the partitioned layout."""
        z = jnp.zeros((1,), jnp.int32)
        return g._replace(senders=z, receivers=z,
                          edge_mask=jnp.zeros((1,), bool))

    def set_executor_pool(self, n_executors: int) -> None:
        """Install (``n > 1``) or tear down (``n <= 1``) the per-bucket
        match fan-out pool (DESIGN.md §10). Pool workers only launch the
        independent per-bucket jit dispatches — on inputs identical to the
        serial path — and the fan-in join happens in bucket order before
        any merge, so pooled results are bit-identical to serial ones.
        Host-side step decisions (seed memo, PEM, merge) never leave the
        calling thread."""
        if self._exec_pool is not None:
            self._exec_pool.shutdown(wait=True)
            self._exec_pool = None
        if n_executors > 1:
            self._exec_pool = ThreadPoolExecutor(
                max_workers=n_executors,
                thread_name_prefix="rt-bucket-exec")

    def _label_table(self, g: DynamicGraph,
                     r0: Optional[jnp.ndarray] = None,
                     iters: Optional[int] = None, ell=None,
                     part=None, sharded: bool = False) -> jnp.ndarray:
        """The per-step label-RWR table — the single biggest sweep cost.

        ``sharded`` marks a FULL-graph call (storm/batch), which runs over
        the graph mesh axis when one is configured (``ell`` then being the
        shard-local mirror); induced-subgraph tables stay replicated.
        ``cfg.rwr_tol > 0`` swaps the fixed-count scan for the residual-
        adaptive loop (hard cap = the fixed count), and the sweeps
        actually run are accounted in ``self.rwr_sweeps``.
        """
        cfg = self.cfg
        iters = iters if iters is not None else cfg.rwr_iters
        if sharded and self._sweeps is not None:
            r, n, skipped = self._sweeps.label_table(
                g, cfg.n_labels, iters, cfg.restart_prob, r0, ell,
                tol=cfg.rwr_tol, part=part)
            self._account_sweeps(int(n), int(skipped))
            # decommit from the sweep mesh: bucket meshes may span a
            # different device set, and multi-device-committed inputs do
            # not transfer implicitly. The (n, L) table is tiny next to
            # the sweeps it took to produce.
            return jnp.asarray(np.asarray(r))
        if cfg.rwr_tol > 0:
            r, n, skipped = label_rwr_adaptive(
                g, cfg.n_labels, max_iters=iters, tol=cfg.rwr_tol,
                c=cfg.restart_prob, r0=r0, ell=ell)
            self._account_sweeps(int(n), int(skipped))
            return r
        self._account_sweeps(iters, 0)
        return label_rwr(g, cfg.n_labels, iters=iters,
                         c=cfg.restart_prob, r0=r0, ell=ell)

    def _account_sweeps(self, n: int, skipped: int) -> None:
        self.rwr_sweeps += n
        self.rwr_cols_skipped += skipped
        self._last_sweeps = n
        self._last_cols_skipped = skipped

    def _merge(self, results, remap=None,
               rebuild: bool = False) -> Tuple[QueryDelta, ...]:
        """Fold per-bucket results into the per-query stores (the only
        per-query host work of a step). Traced per bucket and per row —
        the per-alias store fan-out here was the host cost that grew the
        bank1024 step while device work stayed flat (ROADMAP). Alias
        stores created while the primary was empty SHARE the primary's
        pattern dict (see ``PatternStore.share_from``), so each row folds
        its arrays once per *distinct dict* in the group — O(1) for fully
        shared groups — and the remaining per-alias work is a dict lookup
        to emit the QueryDelta. ``last_merge_rows``/``last_merge_stores``
        keep the fan-out accounting; ``last_merge_folds`` counts the
        actual merge_arrays calls (== rows when every group is shared)."""
        obs = self.obs
        by_qid: Dict[str, QueryDelta] = {}
        n_rows = n_stores = n_folds = 0
        for shape, res in results.items():
            bucket = self.buckets[shape]
            with obs.span("engine/merge/bucket",
                          bucket=f"{shape[0]}x{shape[1]}",
                          rows=bucket.n_live):
                matched = np.asarray(res.matched)
                if remap is not None:
                    matched = remap_matched(
                        matched.reshape(-1, matched.shape[-1]),
                        remap).reshape(matched.shape)
                goodness = np.asarray(res.goodness)
                exact = np.asarray(res.exact)
                valid = np.asarray(res.valid)
                for slot, qid in bucket.rows():
                    # one device row serves its whole duplicate group: the
                    # primary (owning the row) plus every alias store
                    group = self._dups.get(self._sig_of[qid], [qid])
                    n_rows += 1
                    n_stores += len(group)
                    folded: Dict[int, int] = {}  # id(pattern dict) → n_new
                    with obs.span("engine/merge/row", qid=qid,
                                  aliases=len(group)):
                        for alias in group:
                            store = self.stores[alias]
                            pid = id(store._patterns)
                            if pid not in folded:
                                if rebuild:
                                    store._patterns.clear()
                                folded[pid] = store.merge_arrays(
                                    matched[slot], goodness[slot],
                                    exact[slot], valid[slot],
                                    bucket.row_mask(slot))
                                n_folds += 1
                            name = (bucket.query(slot).name if alias == qid
                                    else self._alias_query[alias].name)
                            by_qid[alias] = QueryDelta(alias, name,
                                                       folded[pid],
                                                       store.total,
                                                       store.exact)
        self.last_merge_rows = n_rows
        self.last_merge_stores = n_stores
        self.last_merge_folds = n_folds
        return tuple(by_qid[q] for q in self._order if q in by_qid)

    # -- whole-engine checkpointing (DESIGN.md §4) ------------------------------

    def state_dict(self, state: EngineState) -> Dict:
        """The engine's EngineState pytree as host arrays: graph, the
        warm-start r_lab table, per-bucket bank tables, PEM/DQN state, and
        the pattern-store arrays. The ELL mirror and Louvain dendrogram are
        deliberately absent — they are caches rebuilt from the graph."""
        n, L = self.cfg.n_max, self.cfg.n_labels
        d: Dict = {
            "graph": {f: np.asarray(getattr(state.graph, f))
                      for f in state.graph._fields},
            "r_lab": (np.zeros((n, L), np.float32) if state.r_lab is None
                      else np.asarray(state.r_lab)),
            "has_rlab": np.asarray(state.r_lab is not None),
            "rlab_events": np.asarray(state.rlab_events, np.int64),
            "step_idx": np.asarray(state.step_idx, np.int64),
            "buckets": {f"{k[0]}x{k[1]}": b.bank_arrays()
                        for k, b in self.buckets.items()},
            "stores": {qid: self.stores[qid].to_arrays()
                       for qid in self._order},
            # qid → primary-row aliases of the exact-duplicate groups
            # (uint8-encoded "alias\tprimary" lines; round-trip guard —
            # a load against the same registry must reproduce them)
            "aliases": encode_strings(
                f"{a}\t{self._dups[self._sig_of[a]][0]}"
                for a in self._order if a in self._alias_query),
        }
        if self.pem is not None:
            d["pem"] = {"community_size": np.asarray(self.pem.c, np.int64)}
            if self.pem.agent is not None:
                d["pem"]["agent"] = self.pem.agent.state_dict()
        if self.control is not None:
            d["control"] = self.control.state_dict()
        return d

    def save(self, state: EngineState, directory: str,
             step: Optional[int] = None) -> None:
        ckpt = Checkpointer(directory, async_save=False)
        ckpt.save(state.step_idx if step is None else step,
                  self.state_dict(state))

    def load(self, state: EngineState, directory: str,
             step: Optional[int] = None) -> Tuple[EngineState, int]:
        """Restore a checkpoint saved by :meth:`save`. The same queries
        must be registered (the registry is code+configuration; the
        checkpoint carries data). Returns (state, step)."""
        ckpt = Checkpointer(directory, async_save=False)
        tree, step = ckpt.restore(self.state_dict(state), step=step)
        graph = DynamicGraph(**{f: jnp.asarray(tree["graph"][f])
                                for f in DynamicGraph._fields})
        for key_s, arrays in tree["buckets"].items():
            q, qe = (int(x) for x in key_s.split("x"))
            self.buckets[(q, qe)].load_bank_arrays(arrays)
        if "aliases" in tree:
            live = tuple(f"{a}\t{self._dups[self._sig_of[a]][0]}"
                         for a in self._order if a in self._alias_query)
            if decode_strings(np.asarray(tree["aliases"])) != live:
                raise ValueError(
                    "checkpointed duplicate-alias groups do not match the "
                    "live registry — register the same queries before "
                    "load()")
        for qid, arrays in tree["stores"].items():
            self.stores[qid].load_arrays(arrays)
        self._reshare_alias_stores()
        if self.pem is not None:
            self.pem.c = int(tree["pem"]["community_size"])
            if self.pem.agent is not None:
                self.pem.agent.load_state_dict(tree["pem"]["agent"])
        if self.control is not None and "control" in tree:
            self.control.load_state_dict(tree["control"])
        self._seed_memo.clear()
        if self.pem is not None:
            # the Louvain dendrogram is stale-tolerant (results-affecting)
            # state, not a pure cache: drop it so an in-process load behaves
            # exactly like a fresh process restoring the same checkpoint
            self.pem.reset_clustering()
        # the ELL mirror resyncs on the next _apply (graph identity changed)
        return EngineState(
            graph=graph,
            r_lab=(jnp.asarray(tree["r_lab"]) if bool(tree["has_rlab"])
                   else None),
            rlab_events=int(tree["rlab_events"]),
            rlab_version=0,
            step_idx=int(tree["step_idx"])), step


def _n_events(upd: UpdateBatch) -> int:
    """Masked update entries in a batch (host-side; staleness accounting)."""
    return int(np.asarray(upd.add_mask).sum()
               + np.asarray(upd.rem_mask).sum()
               + np.asarray(upd.lab_mask).sum())


def engine_step(eng: Engine, state: EngineState,
                upd: UpdateBatch) -> Tuple[EngineState, StepOutput]:
    """THE shared step pipeline (module docstring). Pure in the functional-
    core sense: evolving data is read from ``state`` and returned in the
    new state; Engine-held host caches are rebuilt-on-demand views.

    With tracing off this delegates straight to the pipeline — no span
    objects, no stage dict, no extra device fences (the no-op path the
    bitwise/trace-count tests pin). With tracing on, the step runs inside
    a step-scoped trace context (every span carries ``step``), the flight
    recorder captures the step's span group, and per-stage wall times
    come back in ``StepOutput.stage_s``."""
    obs = eng.obs
    if not obs.enabled:
        return _engine_step(eng, state, upd, obs, None)
    step_idx = int(state.step_idx)
    with obs.profile_step(step_idx), obs.context(step=step_idx):
        obs.begin_step(step_idx)
        try:
            return _engine_step(eng, state, upd, obs, {})
        finally:
            obs.end_step(step_idx)


def _run_matches(eng: Engine, jobs, obs: Obs, tracing: bool):
    """Dispatch the per-bucket bank matches: serially without an executor
    pool, fanned across the pool otherwise, with a fan-in join in bucket
    submission order (the merge barrier) before returning. Each job is
    ``(shape, bucket_key, thunk)``; buckets are independent jit dispatches
    on identical inputs either way, so pooled results are bit-identical
    to serial ones and ``results`` keeps bucket-insertion order. Pooled
    ``t_gray`` sums per-worker seconds (may exceed wall time)."""
    results = {}
    t_gray = t_gwait = 0.0
    pool = eng._exec_pool
    if pool is None or len(jobs) <= 1:
        for shape, bkey, thunk in jobs:
            with obs.span("engine/gray", bucket=bkey) as sp:
                results[shape] = thunk()
            t_gray += sp.dur_s
            if tracing:
                with obs.span("engine/gray_wait", bucket=bkey) as spw:
                    jax.block_until_ready(results[shape])
                t_gwait += spw.dur_s
        return results, t_gray, t_gwait

    # collective-bearing programs (graph mesh sharded over >1 device) must
    # not be launched concurrently: XLA orders collectives only within a
    # launch, so two in-flight all_gathers over the same device set reach
    # different rendezvous and deadlock. Serialize dispatch AND completion
    # through the engine lock; a 1-device mesh has no collectives, and
    # concurrent jit launches on one device are safe, so it runs lock-free.
    lock = eng._dispatch_lock if eng.g_shards > 1 else None

    def run(bkey, thunk):
        with obs.span("engine/gray", bucket=bkey, pooled=True) as sp:
            if lock is not None:
                with lock:
                    out = thunk()
                    jax.block_until_ready(out)
            else:
                out = thunk()
                if tracing:
                    jax.block_until_ready(out)
        return out, sp.dur_s

    futs = [(shape, pool.submit(run, bkey, thunk))
            for shape, bkey, thunk in jobs]
    for shape, fut in futs:
        out, dur = fut.result()
        results[shape] = out
        t_gray += dur
    return results, t_gray, t_gwait


def _engine_step(eng: Engine, state: EngineState, upd: UpdateBatch,
                 obs: Obs, stage: Optional[Dict[str, float]]
                 ) -> Tuple[EngineState, StepOutput]:
    """Pipeline body. ``stage`` is None when tracing is disabled (all
    span calls then hit the shared no-op span); when tracing, it
    accumulates per-stage seconds for ``StepOutput.stage_s``. Stage
    taxonomy (DESIGN.md §8): apply → prune → pem → [storm: rwr → seeds →
    gray | induced: extract → rwr → gray] → device_wait → merge →
    feedback. Extra ``block_until_ready`` fences that split host
    dispatch from device wait run ONLY under ``obs.enabled``."""
    cfg, ecfg = eng.cfg, eng.ecfg
    tracing = stage is not None
    with obs.span("engine/apply") as sp:
        g, refresh_s = eng._apply(state.graph, upd)
        n_events = _n_events(upd)
        rlab_events = state.rlab_events + n_events
        rlab_version = state.rlab_version
        upd_ids = None
        if ecfg.mode != "batch":
            ids, mask = updated_vertices(g, upd, ecfg.v_max)
            upd_ids = np.asarray(jnp.where(mask, ids, -1))
        jax.block_until_ready(g)
    if tracing:
        stage["apply"] = sp.dur_s
        stage["ell_refresh"] = refresh_s

    # -- store pruning (deletion-heavy streams; DESIGN.md §3) -----------------
    n_pruned = 0
    if (ecfg.mode != "batch"
            and any(s.total for s in eng.stores.values())
            and bool(np.asarray(upd.rem_mask).any())):
        with obs.span("engine/prune") as sp:
            live = live_vertex_mask(g)
            # prune each DISTINCT pattern dict once (alias stores share
            # the primary's dict); every sharer still counts the removals,
            # preserving the per-store n_pruned arithmetic
            removed: Dict[int, int] = {}
            for s in eng.stores.values():
                pid = id(s._patterns)
                if pid not in removed:
                    removed[pid] = s.prune(live)
                n_pruned += removed[pid]
        if tracing:
            stage["prune"] = sp.dur_s

    t0 = time.perf_counter()
    n_live = max(int(np.asarray(g.node_mask).sum()), 1)
    rlab_hit = seed_hit = False
    community = 0
    rl_loss = 0.0
    t_seeds = t_gray = t_gwait = 0.0

    eng._last_sweeps = 0
    eng._last_cols_skipped = 0
    if ecfg.mode == "batch":
        # the paper's Batch oracle: full fresh pass, stores rebuilt
        frac = 0.0
        n_rec = n_live
        storm = True
        ell = eng._full_ell
        part = eng._full_part
        # partitioned storage: the mesh programs read edges from the
        # PartitionedEdges slices, so ship a node-only view of g and keep
        # the replicated COO arrays off the devices entirely
        g_mesh = eng._node_view(g) if part is not None else g
        with obs.span("engine/rwr", mode="batch") as sp:
            r_lab = eng._label_table(g_mesh, ell=ell, part=part,
                                     sharded=True)
            if tracing:
                jax.block_until_ready(r_lab)
        if tracing:
            stage["rwr"] = sp.dur_s
        jobs = [(shape, f"{shape[0]}x{shape[1]}",
                 (lambda b=bucket: b.match(g_mesh, r_lab, ell=ell,
                                           graph_sharded=True, part=part)))
                for shape, bucket in eng.buckets.items()]
        results, t_gray, t_gwait = _run_matches(eng, jobs, obs, tracing)
        with obs.span("engine/device_wait") as sp:
            jax.block_until_ready(list(results.values()))
        elapsed = time.perf_counter() - t0
        if tracing:
            stage["gray"] = t_gray
            stage["device_wait"] = t_gwait + sp.dur_s
        with obs.span("engine/merge") as sp:
            deltas = eng._merge(results, rebuild=True)
        if tracing:
            stage["merge"] = sp.dur_s
            obs.instant("engine/merge/fanout", rows=eng.last_merge_rows,
                        stores=eng.last_merge_stores,
                        folds=eng.last_merge_folds)
        sub_n = sub_e = 0
        r_lab = None  # batch mode keeps no warm-start state
        rlab_events = 0
    else:
        with obs.span("engine/pem") as sp:
            rec_mask, frac = eng.pem.recompute_mask(g, upd_ids)
            n_rec = int(rec_mask.sum())
        if tracing:
            stage["pem"] = sp.dur_s
        storm = n_rec > ecfg.full_graph_frac * n_live

        if storm:
            # update storm — full pass, warm-started label RWR (paper: "too
            # many vertices updated to be re-computed" case), gated by the
            # staleness-keyed seed cache
            ell = eng._full_ell
            part = eng._full_part
            g_mesh = eng._node_view(g) if part is not None else g
            if (ecfg.seed_cache_staleness > 0 and state.r_lab is not None
                    and rlab_events <= ecfg.seed_cache_staleness):
                r_lab = state.r_lab
                rlab_hit = True
                eng.rlab_hits += 1
                if tracing:
                    stage["rwr"] = 0.0
                    obs.instant("engine/rwr_cache_hit")
            else:
                # warm starts under the residual-adaptive loop keep the
                # full hard cap — convergence is measured, not assumed
                with obs.span("engine/rwr", mode="storm",
                              warm=state.r_lab is not None) as sp:
                    r_lab = eng._label_table(
                        g_mesh, r0=state.r_lab,
                        iters=(None if (state.r_lab is None
                                        or cfg.rwr_tol > 0)
                               else cfg.rwr_iters_incremental),
                        ell=ell, part=part, sharded=True)
                    if tracing:
                        jax.block_until_ready(r_lab)
                if tracing:
                    stage["rwr"] = sp.dur_s
                rlab_events = 0
                rlab_version += 1
                eng.rlab_misses += 1
            sf = jnp.asarray(rec_mask)
            mask_arr = np.asarray(rec_mask, bool)
            jobs = []
            bucket_hits = []
            for shape, bucket in eng.buckets.items():
                bkey = f"{shape[0]}x{shape[1]}"
                ver_key = (rlab_version, bucket.version)
                hit = eng._seed_memo.get(shape)
                # bounded-divergence reuse: same table/bank versions and a
                # recompute mask within seed_cache_hamming flips of the
                # one the cached seeds were ranked under (0 = exact match)
                ham = (int(np.count_nonzero(hit[1] != mask_arr))
                       if hit is not None and hit[0] == ver_key else None)
                if ham is not None and ham <= ecfg.seed_cache_hamming:
                    seeds = hit[2]
                    bucket_hits.append(True)
                    eng.seed_hits += 1
                    if ham == 0:
                        eng.seed_hits_exact += 1
                    else:
                        eng.seed_hits_bounded += 1
                else:
                    with obs.span("engine/seeds", bucket=bkey) as sp:
                        seeds = bucket.seeds(g, r_lab, sf)
                        if tracing:
                            jax.block_until_ready(seeds)
                    t_seeds += sp.dur_s
                    eng._seed_memo[shape] = (ver_key, mask_arr, seeds)
                    bucket_hits.append(False)
                    eng.seed_misses += 1
                jobs.append((shape, bkey,
                             (lambda b=bucket, s=seeds:
                              b.match(g_mesh, r_lab, seed_filter=sf,
                                      ell=ell, seeds=s,
                                      graph_sharded=True, part=part))))
            seed_hit = bool(bucket_hits) and all(bucket_hits)
            results, t_gray, t_gwait = _run_matches(eng, jobs, obs, tracing)
            with obs.span("engine/device_wait") as sp:
                jax.block_until_ready(list(results.values()))
            elapsed = time.perf_counter() - t0
            if tracing:
                stage["seeds"] = t_seeds
                stage["gray"] = t_gray
                stage["device_wait"] = t_gwait + sp.dur_s
            with obs.span("engine/merge") as sp:
                deltas = eng._merge(results)
            if tracing:
                stage["merge"] = sp.dur_s
                obs.instant("engine/merge/fanout",
                            rows=eng.last_merge_rows,
                            stores=eng.last_merge_stores,
                            folds=eng.last_merge_folds)
            sub_n, sub_e = n_live, int(np.asarray(g.edge_mask).sum())
        else:
            with obs.span("engine/extract") as sp:
                sub = extract_induced(
                    g, rec_mask,
                    ell_k=(cfg.ell_width if eng.ell_cache is not None
                           else None))
            if tracing:
                stage["extract"] = sp.dur_s
            with obs.span("engine/rwr", mode="induced") as sp:
                r_sub = eng._label_table(sub.graph, ell=sub.ell)
                if tracing:
                    jax.block_until_ready(r_sub)
            if tracing:
                stage["rwr"] = sp.dur_s
            jobs = [(shape, f"{shape[0]}x{shape[1]}",
                     (lambda b=bucket: b.match(sub.graph, r_sub,
                                               ell=sub.ell)))
                    for shape, bucket in eng.buckets.items()]
            results, t_gray, t_gwait = _run_matches(eng, jobs, obs, tracing)
            with obs.span("engine/device_wait") as sp:
                jax.block_until_ready(list(results.values()))
            elapsed = time.perf_counter() - t0
            if tracing:
                stage["gray"] = t_gray
                stage["device_wait"] = t_gwait + sp.dur_s
            with obs.span("engine/merge") as sp:
                deltas = eng._merge(results, remap=sub.local_to_global)
            if tracing:
                stage["merge"] = sp.dur_s
                obs.instant("engine/merge/fanout",
                            rows=eng.last_merge_rows,
                            stores=eng.last_merge_stores,
                            folds=eng.last_merge_folds)
            sub_n, sub_e = sub.n_nodes, sub.n_edges
            r_lab = state.r_lab  # full-graph warm start unchanged

        with obs.span("engine/pem_feedback") as sp:
            community, rl_loss = eng.pem.feedback(g, frac, elapsed)
        if tracing:
            stage["feedback"] = sp.dur_s

    new_state = state.evolve(graph=g, r_lab=r_lab, rlab_events=rlab_events,
                             rlab_version=rlab_version,
                             step_idx=state.step_idx + 1)
    out = StepOutput(
        step=state.step_idx, elapsed=elapsed, n_recompute=n_rec,
        frac_affected=frac, community_size=community, rl_loss=rl_loss,
        storm=storm, subgraph_nodes=sub_n, subgraph_edges=sub_e,
        ell_refresh_s=refresh_s, n_pruned=n_pruned, n_events=n_events,
        rlab_cache_hit=rlab_hit, seed_cache_hit=seed_hit,
        rwr_sweeps=eng._last_sweeps,
        rwr_cols_skipped=eng._last_cols_skipped, deltas=deltas,
        stage_s=stage)
    return new_state, out
