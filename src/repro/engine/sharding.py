"""Device-sharded bucket execution — the (query × graph) mesh.

Two independent mesh axes (DESIGN.md §4/§5):

``"q"`` — rows of a bucket bank are independent programs in the
content-independent (``memo=False``) schedule, so the bank match
parallelizes over the query axis with ZERO collectives: ``shard_map``
splits the bank tensors and the per-row seeds, every device runs the same
expansion on its row slice, and the results concatenate back.

``"g"`` — vertices of the data graph partition into contiguous receiver
slices, which is what lets ``n_max`` scale past one device: the COO sweep
masks messages to the shard's slice and combines partial segment-sums
with a ``psum``, and the ELL mirror carries a per-shard row-block layout
(``EllCache(n_shards=…)`` — slice-local ``row_ids``, one spill cursor per
block) so each device's Pallas launch touches only its vertex slice and
the slices ``all_gather`` back. Non-owner shards contribute exact zeros
and concatenation does no arithmetic, so BOTH axes are pure
distributions: sharded results are bit-identical to the replicated path
on both backends (pinned in ``tests/test_engine_sharding.py`` and
``tests/test_graph_sharding.py`` under 4 forced host devices).

Falls back to the plain jit path when one device is visible; shard counts
are capped at the largest power of two dividing both the device count and
the sharded dimension, so every shard carries the same static slice. When
both axes are ``"auto"`` the device pool splits between them (graph axis
≤ √devices); an ``"off"`` query axis frees every device for the graph
axis.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax ≥ 0.6 promoted shard_map out of experimental
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map

from repro.core.graph import DynamicGraph, PartitionedEdges
from repro.core.gray import GRayResult, _bfs_reach_hops
from repro.core.query import QueryBank
from repro.core.rwr import label_rwr, label_rwr_adaptive, rwr, rwr_adaptive
from repro.sparse.ell import EllGraph

_REP = P()


def _pow2_cap(cap: int) -> int:
    n = 1
    while n * 2 <= cap:
        n *= 2
    return n


def query_shard_count(b_pad: int, shard: str = "auto",
                      max_devices: Optional[int] = None) -> int:
    """Shards for a ``b_pad``-row bucket: the largest pow-2 ≤ min(devices,
    rows). 1 disables the shard_map path (plain jit + vmap).
    ``max_devices`` caps the device budget (the rest belong to the graph
    axis)."""
    if shard == "off":
        return 1
    if shard != "auto":
        raise ValueError(f"unknown shard policy {shard!r}")
    nd = len(jax.devices()) if max_devices is None else max_devices
    return _pow2_cap(min(nd, b_pad))


def graph_shard_count(n_max: int, shard: str = "off",
                      max_devices: Optional[int] = None) -> int:
    """Shards of the graph mesh axis: the largest pow-2 ≤ devices that
    divides ``n_max`` (equal static vertex slices). ``"off"`` pins the
    replicated graph."""
    if shard == "off":
        return 1
    if shard != "auto":
        raise ValueError(f"unknown graph shard policy {shard!r}")
    nd = len(jax.devices()) if max_devices is None else max_devices
    n = 1
    while n * 2 <= min(nd, n_max) and n_max % (n * 2) == 0:
        n *= 2
    return n


def device_split(shard: str, graph_shard: str,
                 n_max: int) -> Tuple[int, int]:
    """How the visible devices split between the two mesh axes.

    Returns ``(query_budget, g_shards)``: the graph axis takes every
    device when the query axis is off, at most √devices when both are
    auto (a balanced 2-D mesh), and the query axis gets the rest.
    """
    nd = len(jax.devices())
    if graph_shard == "off":
        return nd, 1
    cap = nd if shard == "off" else _pow2_cap(int(np.sqrt(nd)))
    g = graph_shard_count(n_max, graph_shard, max_devices=max(cap, 1))
    return max(nd // g, 1), g


class ShardedBankMatch:
    """``shard_map`` wrapper around one bucket matcher's ``_match_impl``.

    ``n_shards`` splits the bank's row axis over ``"q"``; ``g_shards > 1``
    adds the ``"g"`` graph axis. A call with ``graph_sharded=True`` (the
    engine's storm/batch full-graph path) expects the shard-local ELL
    row-block mirror and runs the matcher's sweeps with ``axis="g"``;
    ``graph_sharded=False`` (the induced-subgraph path, whose compact
    extraction is already the speedup) keeps the graph replicated over
    ``"g"`` and the sweeps collective-free.
    """

    def __init__(self, matcher, n_shards: int, g_shards: int = 1):
        assert not matcher.memo, "sharded buckets require memo=False"
        self.matcher = matcher
        self.n_shards = n_shards
        self.g_shards = g_shards
        devs = np.asarray(jax.devices()[:n_shards * g_shards])
        self.mesh = Mesh(devs.reshape(n_shards, g_shards), ("q", "g"))
        self._fns = {}  # keyed (ell present, graph sharded, plan, part)

    def _build(self, g: DynamicGraph, ell: Optional[EllGraph],
               part: Optional[PartitionedEdges], graph_sharded: bool,
               has_plan: bool):
        rep, q = _REP, P("q")
        axis = "g" if (graph_sharded and self.g_shards > 1) else None
        g_spec = jax.tree.map(lambda _: rep, g)
        bank_specs = (q,) * 7  # labels, mask, anchor, order_* — all row-major
        # the row_node plan splits with the rows: each shard resolves the
        # DAG nodes its local rows read and computes them independently
        # (node tables are replicated work, rows stay collective-free)
        plan_specs = (q,) if has_plan else ()
        out_specs = GRayResult(q, q, q, q, q)
        # edge carriers (mutually exclusive): the ELL mirror replicates
        # without a graph axis, the partitioned COO slices only exist ON
        # the graph axis (each device receives its receiver slice)
        extra_specs = ()
        if ell is not None:
            extra_specs += (jax.tree.map(
                lambda _: P("g") if axis is not None else rep, ell),)
        if part is not None:
            assert axis is not None and ell is None
            extra_specs += (jax.tree.map(lambda _: P("g"), part),)
        n_extra = len(extra_specs)

        def f(g_, r_lab, seed_ids, seed_mask, *rest):
            ell_ = rest[0] if ell is not None else None
            part_ = rest[n_extra - 1] if part is not None else None
            labels, mask, anchor, osrc, odst, otree, omask = \
                rest[n_extra:n_extra + 7]
            plan = rest[n_extra + 7:]
            return self.matcher._match_impl(
                g_, r_lab, seed_ids, seed_mask, ell_, labels, mask,
                anchor, osrc, odst, otree, omask,
                plan[0] if plan else None, part_, graph_axis=axis)

        in_specs = (g_spec, rep, q, q) + extra_specs + bank_specs + plan_specs
        return jax.jit(shard_map(f, mesh=self.mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False))

    def __call__(self, g: DynamicGraph, r_lab: jnp.ndarray,
                 seed_ids: jnp.ndarray, seed_mask: jnp.ndarray,
                 ell: Optional[EllGraph], bank: QueryBank,
                 graph_sharded: bool = False,
                 row_node: Optional[jnp.ndarray] = None,
                 part: Optional[PartitionedEdges] = None) -> GRayResult:
        # without a graph axis, graph_sharded compiles the identical
        # program — normalize so storm and induced calls share one trace
        graph_sharded = graph_sharded and self.g_shards > 1
        if not graph_sharded:
            part = None  # partitioned slices only exist on the graph axis
        key = (ell is not None, graph_sharded, row_node is not None,
               part is not None)
        if key not in self._fns:
            self._fns[key] = self._build(g, ell, part, graph_sharded,
                                         row_node is not None)
        args = (g, r_lab, seed_ids, seed_mask)
        if ell is not None:
            args = args + (ell,)
        if part is not None:
            args = args + (part,)
        args = args + (bank.labels, bank.mask, bank.anchor,
                       bank.order_src, bank.order_dst,
                       bank.order_tree, bank.order_mask)
        if row_node is not None:
            args = args + (row_node,)
        return self._fns[key](*args)

    def trace_count(self) -> int:
        n = 0
        for fn in self._fns.values():
            size = getattr(fn, "_cache_size", None)
            n += size() if size is not None else 0
        return n


class ShardedSweep:
    """Graph-axis ``shard_map`` programs for the full-graph sweeps.

    The engine drives :meth:`label_table` (the per-step label-RWR hot
    path); :meth:`run_rwr` / :meth:`reach` expose the raw sweeps so the
    bitwise-equivalence tests exercise exactly the production programs.
    ELL mirrors must be the shard-local row-block layout
    (``EllCache(n_shards=g_shards)``); COO graphs stay replicated and the
    partial scatter combines with a ``psum``.
    """

    def __init__(self, g_shards: int):
        self.g_shards = g_shards
        self.mesh = Mesh(np.asarray(jax.devices()[:g_shards]), ("g",))
        self._fns = {}

    def _specs(self, has_r0: bool, ell: Optional[EllGraph],
               g: DynamicGraph, *extra,
               part: Optional[PartitionedEdges] = None):
        g_spec = jax.tree.map(lambda _: _REP, g)
        specs = (g_spec,) + tuple(_REP for _ in extra)
        if has_r0:
            specs = specs + (_REP,)
        # edge carriers are mutually exclusive and always shard over "g"
        # (the partitioned slices only exist on the graph axis)
        if ell is not None:
            specs = specs + (jax.tree.map(lambda _: P("g"), ell),)
        if part is not None:
            assert ell is None
            specs = specs + (jax.tree.map(lambda _: P("g"), part),)
        return specs

    def _call(self, key, build, *args):
        fn = self._fns.get(key)
        if fn is None:
            fn = self._fns[key] = build()
        return fn(*args)

    def label_table(self, g: DynamicGraph, n_labels: int, iters: int,
                    c: float, r0: Optional[jnp.ndarray],
                    ell: Optional[EllGraph], tol: float = 0.0,
                    part: Optional[PartitionedEdges] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Sharded :func:`label_rwr` → ``(r_lab, n_sweeps, n_col_skipped)``
        (the sweep count is ``iters`` on the fixed path, measured when
        ``tol > 0``; the converged-column skip count is 0 on the fixed
        path)."""
        has_r0, has_ell = r0 is not None, ell is not None
        has_part = part is not None
        key = ("lab", has_ell, has_part, has_r0, n_labels, iters, c, tol)

        def build():
            def f(g_, *rest):
                r0_ = rest[0] if has_r0 else None
                # edge carriers are mutually exclusive, both appended last
                ell_ = rest[-1] if has_ell else None
                part_ = rest[-1] if has_part else None
                if tol > 0:
                    return label_rwr_adaptive(
                        g_, n_labels, max_iters=iters, tol=tol, c=c,
                        r0=r0_, ell=ell_, axis="g", part=part_)
                return (label_rwr(g_, n_labels, iters=iters, c=c, r0=r0_,
                                  ell=ell_, axis="g", part=part_),
                        jnp.int32(iters), jnp.int32(0))

            return jax.jit(shard_map(
                f, mesh=self.mesh,
                in_specs=self._specs(has_r0, ell, g, part=part),
                out_specs=(_REP, _REP, _REP), check_rep=False))

        args = ((g,) + ((r0,) if has_r0 else ())
                + ((ell,) if has_ell else ())
                + ((part,) if has_part else ()))
        return self._call(key, build, *args)

    def run_rwr(self, g: DynamicGraph, e: jnp.ndarray, iters: int,
                c: float = 0.15, r0: Optional[jnp.ndarray] = None,
                ell: Optional[EllGraph] = None, tol: float = 0.0,
                part: Optional[PartitionedEdges] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Sharded :func:`rwr` / :func:`rwr_adaptive` →
        ``(r, n_sweeps, n_col_skipped)``."""
        has_r0, has_ell = r0 is not None, ell is not None
        has_part = part is not None
        key = ("rwr", has_ell, has_part, has_r0, iters, c, tol)

        def build():
            def f(g_, e_, *rest):
                r0_ = rest[0] if has_r0 else None
                ell_ = rest[-1] if has_ell else None
                part_ = rest[-1] if has_part else None
                if tol > 0:
                    return rwr_adaptive(g_, e_, max_iters=iters, tol=tol,
                                        c=c, r0=r0_, ell=ell_, axis="g",
                                        part=part_)
                return (rwr(g_, e_, iters=iters, c=c, r0=r0_, ell=ell_,
                            axis="g", part=part_), jnp.int32(iters),
                        jnp.int32(0))

            return jax.jit(shard_map(
                f, mesh=self.mesh,
                in_specs=self._specs(has_r0, ell, g, e, part=part),
                out_specs=(_REP, _REP, _REP), check_rep=False))

        args = ((g, e) + ((r0,) if has_r0 else ())
                + ((ell,) if has_ell else ())
                + ((part,) if has_part else ()))
        return self._call(key, build, *args)

    def reach(self, g: DynamicGraph, sources: jnp.ndarray, max_hops: int,
              ell: Optional[EllGraph] = None,
              part: Optional[PartitionedEdges] = None) -> jnp.ndarray:
        """Sharded :func:`~repro.core.gray._bfs_reach_hops`."""
        has_ell, has_part = ell is not None, part is not None
        key = ("reach", has_ell, has_part, max_hops)

        def build():
            def f(g_, src_, *rest):
                ell_ = rest[0] if has_ell else None
                part_ = rest[-1] if has_part else None
                return _bfs_reach_hops(g_, src_, max_hops, ell=ell_,
                                       axis="g", part=part_)

            return jax.jit(shard_map(
                f, mesh=self.mesh,
                in_specs=self._specs(False, ell, g, sources, part=part),
                out_specs=_REP, check_rep=False))

        args = ((g, sources) + ((ell,) if has_ell else ())
                + ((part,) if has_part else ()))
        return self._call(key, build, *args)
