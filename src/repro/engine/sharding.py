"""Device-sharded bucket execution — the query axis over a 1-D mesh.

Rows of a bucket bank are independent programs in the content-independent
(``memo=False``) schedule, so the bank match parallelizes over the query
axis with ZERO collectives: ``shard_map`` splits the bank tensors and the
per-row seeds over a ``("q",)`` mesh, every device runs the same expansion
on its row slice against the replicated graph, and the results concatenate
back along the row axis. Bit-identical to the single-device vmap path —
no cross-row reductions exist to reorder (pinned in
``tests/test_engine_sharding.py`` under 4 forced host devices).

Falls back to the plain jit path when one device is visible; shard counts
are capped at the largest power of two dividing both the device count and
``B_pad``, so every shard carries the same static row slice.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax ≥ 0.6 promoted shard_map out of experimental
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map

from repro.core.graph import DynamicGraph
from repro.core.gray import GRayResult
from repro.core.query import QueryBank
from repro.sparse.ell import EllGraph


def query_shard_count(b_pad: int, shard: str = "auto") -> int:
    """Shards for a ``b_pad``-row bucket: the largest pow-2 ≤ min(devices,
    rows). 1 disables the shard_map path (plain jit + vmap)."""
    if shard == "off":
        return 1
    if shard != "auto":
        raise ValueError(f"unknown shard policy {shard!r}")
    cap = min(len(jax.devices()), b_pad)
    n = 1
    while n * 2 <= cap:
        n *= 2
    return n


class ShardedBankMatch:
    """``shard_map`` wrapper around one bucket matcher's ``_match_impl``."""

    def __init__(self, matcher, n_shards: int):
        assert not matcher.memo, "sharded buckets require memo=False"
        self.matcher = matcher
        self.n_shards = n_shards
        self.mesh = Mesh(np.asarray(jax.devices()[:n_shards]), ("q",))
        self._fns = {}  # keyed by ell presence (distinct arg structure)

    def _build(self, g: DynamicGraph, ell: Optional[EllGraph]):
        rep, q = P(), P("q")
        g_spec = jax.tree.map(lambda _: rep, g)
        bank_specs = (q,) * 7  # labels, mask, anchor, order_* — all row-major
        out_specs = GRayResult(q, q, q, q, q)
        if ell is not None:
            ell_spec = jax.tree.map(lambda _: rep, ell)

            def f(g_, r_lab, seed_ids, seed_mask, ell_, labels, mask, anchor,
                  osrc, odst, otree, omask):
                return self.matcher._match_impl(
                    g_, r_lab, seed_ids, seed_mask, ell_, labels, mask,
                    anchor, osrc, odst, otree, omask)

            in_specs = (g_spec, rep, q, q, ell_spec) + bank_specs
        else:
            def f(g_, r_lab, seed_ids, seed_mask, labels, mask, anchor,
                  osrc, odst, otree, omask):
                return self.matcher._match_impl(
                    g_, r_lab, seed_ids, seed_mask, None, labels, mask,
                    anchor, osrc, odst, otree, omask)

            in_specs = (g_spec, rep, q, q) + bank_specs
        return jax.jit(shard_map(f, mesh=self.mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False))

    def __call__(self, g: DynamicGraph, r_lab: jnp.ndarray,
                 seed_ids: jnp.ndarray, seed_mask: jnp.ndarray,
                 ell: Optional[EllGraph], bank: QueryBank) -> GRayResult:
        key = ell is not None
        if key not in self._fns:
            self._fns[key] = self._build(g, ell)
        args = (g, r_lab, seed_ids, seed_mask)
        if ell is not None:
            args = args + (ell,)
        return self._fns[key](*args, bank.labels, bank.mask, bank.anchor,
                              bank.order_src, bank.order_dst,
                              bank.order_tree, bank.order_mask)

    def trace_count(self) -> int:
        n = 0
        for fn in self._fns.values():
            size = getattr(fn, "_cache_size", None)
            n += size() if size is not None else 0
        return n
